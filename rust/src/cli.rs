//! Command-line interface (hand-rolled — no `clap` offline).
//!
//! ```text
//! coded-coop figure <fig2|fig3|fig4a|fig4b|fig5|fig6|fig7|fig8|all>
//!            [--trials N] [--seed S] [--out DIR] [--fit-samples N]
//! coded-coop plan   --scenario <small|large|ec2|FILE.json>
//!            [--policy P] [--loads markov|exact|sca]
//!            [--values markov|exact] [--gamma-ratio R] [--seed S]
//! coded-coop plan export ... --out plan.json   (plan once…)
//! coded-coop plan run --plan plan.json         (…execute many)
//! coded-coop e2e    [--masters M] [--workers N] [--rows L] [--cols S]
//!            [--policy P] [--seed S] [--native] [--time-scale X]
//!            [--fault SPEC] [--transport thread|tcp] [--workers-at A1,A2,…]
//!            [--auth-token T]
//! coded-coop serve --scenario … --transport tcp [--workers-at A1,A2,…]
//!            [--auth-token T] [--jobs N] [--fault SPEC] [--fast-health]
//! coded-coop worker --listen ADDR [--fault SPEC] [--once] [--auth-token T]
//! coded-coop version | help
//!
//! The shared secret also reads from the `CODED_COOP_AUTH` environment
//! variable (the flag wins), which is how auto-spawned loopback workers
//! inherit it without the token appearing in `ps` output.
//! ```
//!
//! Policy and load-method names resolve through
//! [`crate::policy::registry`], so strategies registered at runtime are
//! immediately addressable from every subcommand.

use crate::assign::ValueModel;
use crate::config::{AShift, CommModel, Scenario};
use crate::coordinator::{self, Backend, RunOptions};
use crate::exec::{self, ExecOptions, Executor};
use crate::net;
use crate::experiment::{self, catalog, CellResult, SweepOptions, SweepSpec};
use crate::figures::{self, FigureOptions};
use crate::health::{FaultPlan, HealthConfig};
use crate::plan::{LoadMethod, Plan, Policy};
use crate::policy::{parse_value_model, registry, PolicySpec};
use crate::runtime::RuntimeService;
use crate::serve::{self, ArrivalProcess, JobRecord};
use crate::util::json::{self, Json};
use crate::util::table::Table;

/// Parsed flag map: `--key value` pairs + positional arguments.
pub struct Args {
    pub positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        flags.push((key.to_string(), it.next().unwrap()));
                    }
                    _ => switches.push(key.to_string()),
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Self {
            positional,
            flags,
            switches,
        })
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }
}

/// Usage text; the policy/load lists come from the live registry so
/// runtime-registered strategies show up.
fn help_text() -> String {
    format!(
        "\
coded-coop — Coded Computation across Shared Heterogeneous Workers (TSP'22)

USAGE:
  coded-coop figure <id|all> [--trials N] [--seed S] [--out DIR] [--fit-samples N]
  coded-coop ablation <redundancy|multimsg|straggler|sca_step|all> [--trials N]
  coded-coop plan --scenario <small|large|ec2|FILE.json> [--policy P]
                  [--loads L] [--values markov|exact]
                  [--gamma-ratio R] [--seed S]
  coded-coop plan export <plan flags> [--out FILE.json]
  coded-coop plan run --plan FILE.json [--executor sim|coordinator]
                  [--trials N] [--seed S] [--cols S] [--time-scale X] [--verify]
  coded-coop sweep list
  coded-coop sweep export --figure <id> [--trials N] [--seed S] [--out FILE.json]
  coded-coop sweep run (--spec FILE.json | --figure <id>) [--trials N]
                  [--seed S] [--threads T] [--cell-streams C]
                  [--order trial_major|blocked|chunked] [--ziggurat] [--fused]
                  [--out results.json]
  coded-coop serve [--figure serving] [--trials N] [--jobs N] [--seed S]
                  [--records FILE] [--no-records] [--out results.json]
  coded-coop serve --scenario <small|large|ec2|FILE.json> [--policy P] [--loads L]
                  [--jobs N] [--load-factor F] [--churn-rate R] [--churn-downtime D]
                  [--fault SPEC]                      (health-derived churn)
                  [--process deterministic|poisson|burst] [--seed S]
                  [--records FILE] [--no-records]
                  [--record-cap N]                    (keep last N job records, stats stay exact)
                  [--event-queue wheel|heap] [--shard] (event core / per-master shards)
  coded-coop serve --scenario … --transport tcp     (lifecycle-observed churn)
                  [--workers-at ADDR1,ADDR2,…] [--auth-token T] [--jobs N]
                  [--cols S] [--time-scale X] [--fault SPEC] [--fast-health]
  coded-coop e2e  [--masters M] [--workers N] [--rows L] [--cols S]
                  [--policy P] [--seed S] [--native] [--time-scale X]
                  [--fault SPEC] [--fast-health]      (fault injection + recovery)
                  [--transport thread|tcp] [--workers-at ADDR1,ADDR2,…]
                  [--auth-token T]                    (or env CODED_COOP_AUTH)
                  [--stream-jobs N] [--period-ms X]   (queued-job stream)
                  [--out FILE.json]                   (full report incl. health events)
  coded-coop worker --listen ADDR [--fault SPEC] [--once] [--auth-token T]
  coded-coop version | help

faults:   SPEC = comma list of kind:worker@frac — e.g. crash:w3@50%,gray:w2@0%,
          spike:w1@25%x40, slow:w4@40%x30, flaky:all@7 (wN 1-based, 'all' = every
          worker, @P% = trigger point in the task queue, xF = extra wall ms).
          --flaky N is deprecated sugar for flaky:all@N.

figures:  fig2 fig3 fig4a fig4b fig5 fig6 fig7 fig8 (see DESIGN.md)
sweeps:   {} (batched grid engine; JSON SweepSpec in, per-cell table + JSON out)
serve:    streams one JSON record per job on stdout (summary table -> stderr);
          use --records FILE to keep stdout for the table
policies: {}
loads:    {}
",
        catalog::IDS.join(" "),
        registry::assigner_names().join(" "),
        registry::public_allocator_names().join(" "),
    )
}

pub fn parse_policy(s: &str) -> anyhow::Result<Policy> {
    Policy::from_name(s).ok_or_else(|| anyhow::anyhow!("unknown policy '{s}'"))
}

pub fn parse_loads(s: &str) -> anyhow::Result<LoadMethod> {
    LoadMethod::from_name(s).ok_or_else(|| anyhow::anyhow!("unknown load method '{s}'"))
}

pub fn parse_values(s: &str) -> anyhow::Result<ValueModel> {
    parse_value_model(s)
}

pub fn parse_scenario(a: &Args) -> anyhow::Result<Scenario> {
    let seed = a.u64_flag("seed", 2022)?;
    let ratio = a.f64_flag("gamma-ratio", 2.0)?;
    let comm = if a.switch("comp-dominant") {
        CommModel::CompDominant
    } else {
        CommModel::Stochastic
    };
    match a.flag("scenario").unwrap_or("small") {
        "small" => Ok(Scenario::small_scale(seed, ratio, comm)),
        "large" => Ok(Scenario::large_scale(seed, ratio, comm)),
        "ec2" => Ok(Scenario::ec2(40, 10, a.switch("stragglers"))),
        path => Scenario::from_file(path),
    }
}

/// Shared-secret auth token: `--auth-token TOKEN` wins, else the
/// `CODED_COOP_AUTH` environment (how auto-spawned workers inherit it
/// without the token ever appearing in `ps` output).
fn auth_token(args: &Args) -> Option<String> {
    args.flag("auth-token")
        .map(str::to_string)
        .or_else(|| std::env::var("CODED_COOP_AUTH").ok().filter(|s| !s.is_empty()))
}

/// `--workers-at A1,A2,…`: explicit worker endpoints (empty/absent =
/// auto-spawn loopback worker processes).
fn workers_at(args: &Args) -> Vec<String> {
    args.flag("workers-at")
        .map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
        .unwrap_or_default()
}

/// Policy spec from `--policy/--values/--loads`, resolved eagerly so
/// unknown names fail with the registry's suggestions.
pub fn parse_policy_spec(a: &Args) -> anyhow::Result<PolicySpec> {
    let spec = PolicySpec::new(
        a.flag("policy").unwrap_or("dedi-iter"),
        parse_values(a.flag("values").unwrap_or("markov"))?,
        a.flag("loads").unwrap_or("markov"),
    );
    spec.resolve()?;
    Ok(spec)
}

/// Entry point for the `coded-coop` binary.
pub fn run() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.positional.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("plan") => cmd_plan(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("serve") => cmd_serve(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("worker") => cmd_worker(&args),
        Some("version") => {
            println!("coded-coop {}", crate::VERSION);
            Ok(())
        }
        Some("help") | None => {
            print!("{}", help_text());
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command '{other}'\n{}", help_text()),
    }
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let opts = FigureOptions {
        trials: args.usize_flag("trials", 100_000)?,
        seed: args.u64_flag("seed", 2022)?,
        fit_samples: args.usize_flag("fit-samples", 200_000)?,
        threads: args.usize_flag("threads", 0)?,
    };
    let ids: Vec<&str> = if id == "all" {
        figures::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let fig = figures::run(id, &opts)?;
        println!("{}", fig.render());
        println!("[{} regenerated in {:.1}s]\n", id, t0.elapsed().as_secs_f64());
        if let Some(dir) = args.flag("out") {
            fig.save(dir)?;
            println!("saved {dir}/{id}.json and {dir}/{id}.txt\n");
        }
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let opts = FigureOptions {
        trials: args.usize_flag("trials", 30_000)?,
        seed: args.u64_flag("seed", 2022)?,
        fit_samples: args.usize_flag("fit-samples", 50_000)?,
        threads: args.usize_flag("threads", 0)?,
    };
    let ids: Vec<&str> = if id == "all" {
        figures::ablations::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let fig = figures::ablations::run(id, &opts)?;
        println!("{}", fig.render());
        if let Some(dir) = args.flag("out") {
            fig.save(dir)?;
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("export") => cmd_plan_export(args),
        Some("run") => cmd_plan_run(args),
        None | Some("show") => cmd_plan_show(args),
        Some(other) => {
            anyhow::bail!("unknown plan subcommand '{other}' (export|run|show)")
        }
    }
}

fn cmd_plan_show(args: &Args) -> anyhow::Result<()> {
    let s = parse_scenario(args)?;
    let spec = parse_policy_spec(args)?;
    let p = spec.build(&s)?;
    println!("scenario: {}", s.name);
    println!("plan:     {}  (t* = {:.3} ms)\n", p.label, p.t_est());
    for (m, mp) in p.masters.iter().enumerate() {
        let mut t = Table::new(&["node", "load l", "k", "b"]);
        for e in &mp.entries {
            let node = if e.node == 0 {
                "local".to_string()
            } else {
                format!("w{}", e.node)
            };
            t.row(&[
                node,
                format!("{:.1}", e.load),
                format!("{:.3}", e.k),
                format!("{:.3}", e.b),
            ]);
        }
        println!(
            "master {} (L = {}, t*_m = {:.3} ms, overhead {:.2}×):\n{}",
            m + 1,
            mp.l_rows,
            mp.t_est,
            mp.total_load() / mp.l_rows,
            t.render()
        );
    }
    Ok(())
}

/// `plan export`: build once, write a self-contained schema-versioned
/// document (spec + scenario + plan) — the cache/shard unit for serving:
/// plan on one box, execute anywhere.
fn cmd_plan_export(args: &Args) -> anyhow::Result<()> {
    let s = parse_scenario(args)?;
    let spec = parse_policy_spec(args)?;
    let plan = spec.build(&s)?;
    let mut doc = Json::obj();
    doc.set("schema", Json::Num(Plan::SCHEMA as f64));
    doc.set("spec", spec.to_json());
    doc.set("scenario", s.to_json());
    doc.set("plan", plan.to_json());
    let text = doc.to_string_pretty();
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!(
                "wrote {path}: {} (t* = {:.3} ms, schema {})",
                plan.label,
                plan.t_est(),
                Plan::SCHEMA
            );
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// `plan run`: execute a previously exported plan document on the chosen
/// [`crate::exec::Executor`] (simulated by default, the real coordinator
/// with `--executor coordinator`).
fn cmd_plan_run(args: &Args) -> anyhow::Result<()> {
    let path = match args.flag("plan") {
        Some(p) => p.to_string(),
        None => args
            .positional
            .get(2)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("plan run needs --plan FILE.json"))?,
    };
    let text = std::fs::read_to_string(&path)?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    if let Some(schema) = doc.get("schema").and_then(Json::as_usize) {
        anyhow::ensure!(
            schema as u64 == Plan::SCHEMA,
            "{path}: document schema {schema} unsupported (this build reads {})",
            Plan::SCHEMA
        );
    }
    let s = Scenario::from_json(
        doc.get("scenario")
            .ok_or_else(|| anyhow::anyhow!("{path}: document missing 'scenario'"))?,
    )?;
    let plan = Plan::from_json(
        doc.get("plan")
            .ok_or_else(|| anyhow::anyhow!("{path}: document missing 'plan'"))?,
    )?;
    plan.validate(&s)
        .map_err(|e| anyhow::anyhow!("{path}: plan does not fit its scenario: {e}"))?;
    let executor = exec::executor_by_name(args.flag("executor").unwrap_or("sim"))?;
    let opts = ExecOptions {
        trials: args.usize_flag("trials", 100_000)?,
        seed: args.u64_flag("seed", 2022)?,
        threads: args.usize_flag("threads", 0)?,
        keep_samples: false,
        cols: args.usize_flag("cols", 64)?,
        time_scale: args.f64_flag("time-scale", 1e-4)?,
        verify: args.switch("verify"),
    };
    let out = executor.execute(&s, &plan, &opts)?;
    println!("scenario: {}", s.name);
    println!(
        "plan:     {}  (t* = {:.3} ms, {} executor)\n",
        out.label,
        out.t_est_ms,
        out.executor
    );
    let mut t = Table::new(&["master", "mean delay (ms)", "planner t* (ms)"]);
    for (m, sm) in out.per_master.iter().enumerate() {
        t.row_fmt(
            &format!("{}", m + 1),
            &[sm.mean(), plan.masters[m].t_est],
            3,
        );
    }
    println!("{}", t.render());
    println!(
        "system delay: {:.3} ms (±{:.3} sem, {} realization{})",
        out.system.mean(),
        out.system.sem(),
        out.system.count(),
        if out.system.count() == 1 { "" } else { "s" },
    );
    Ok(())
}

fn cmd_sweep(args: &Args) -> anyhow::Result<()> {
    match args.positional.get(1).map(String::as_str) {
        Some("export") => cmd_sweep_export(args),
        Some("run") => cmd_sweep_run(args),
        Some("list") | None => cmd_sweep_list(),
        Some(other) => anyhow::bail!("unknown sweep subcommand '{other}' (export|run|list)"),
    }
}

fn cmd_sweep_list() -> anyhow::Result<()> {
    println!("catalog sweep specs (export with: coded-coop sweep export --figure <id>):");
    for id in catalog::IDS {
        let sp = catalog::spec(id, 100_000, 2022)?;
        println!(
            "  {id:<22} {} cells ({} policies{})",
            sp.n_cells()?,
            sp.policies.len(),
            if sp.axes.is_empty() {
                String::new()
            } else {
                format!(
                    ", axes: {}",
                    sp.axes
                        .iter()
                        .map(|a| format!("{}×{}", a.name, a.points.len()))
                        .collect::<Vec<_>>()
                        .join(" ")
                )
            },
        );
    }
    Ok(())
}

/// `sweep export`: write a schema-versioned `SweepSpec` document for a
/// catalog id — declare once, run anywhere (mirrors `plan export`).
fn cmd_sweep_export(args: &Args) -> anyhow::Result<()> {
    let id = args.flag("figure").ok_or_else(|| {
        anyhow::anyhow!("sweep export needs --figure <id> (see 'coded-coop sweep list')")
    })?;
    let spec = catalog::spec(
        id,
        args.usize_flag("trials", 100_000)?,
        args.u64_flag("seed", 2022)?,
    )?;
    let text = spec.to_json().to_string_pretty();
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!(
                "wrote {path}: sweep '{}' ({} cells, schema {})",
                spec.name,
                spec.n_cells()?,
                SweepSpec::SCHEMA
            );
        }
        None => println!("{text}"),
    }
    Ok(())
}

/// `sweep run`: execute a `SweepSpec` (exported JSON or catalog id) on
/// the batched engine; per-cell `Outcome` table + optional JSON out.
fn cmd_sweep_run(args: &Args) -> anyhow::Result<()> {
    let mut spec = match (args.flag("spec"), args.flag("figure")) {
        (Some(path), _) => {
            let text = std::fs::read_to_string(path)?;
            let mut spec = SweepSpec::from_json(
                &json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?,
            )?;
            // Flag overrides, so an exported spec can be smoke-run cheaply.
            if args.flag("trials").is_some() {
                spec.trials = args.usize_flag("trials", spec.trials)?;
            }
            if args.flag("seed").is_some() {
                spec.seed = args.u64_flag("seed", spec.seed)?;
            }
            spec
        }
        (None, Some(id)) => catalog::spec(
            id,
            args.usize_flag("trials", 100_000)?,
            args.u64_flag("seed", 2022)?,
        )?,
        (None, None) => anyhow::bail!("sweep run needs --spec FILE.json or --figure <id>"),
    };
    if let Some(o) = args.flag("order") {
        // Kernel sampling order: `blocked`/`chunked` trade
        // bit-reproducibility against trial-major runs for throughput
        // (same distribution).
        spec.sample_order = crate::sim::SampleOrder::parse(o)?;
    }
    if args.switch("ziggurat") {
        // Kernel v3 exponential sampler; `expand()` enforces the
        // chunked-order requirement with a real error message.
        spec.ziggurat = true;
    }
    let opts = SweepOptions {
        threads: args.usize_flag("threads", 0)?,
        cell_streams: args.usize_flag("cell-streams", 0)?,
        fused: args.switch("fused"),
    };
    let t0 = std::time::Instant::now();
    let result = experiment::run_sweep(&spec, &opts)?;
    println!(
        "sweep: {} ({} cells × {} trials, batched engine)\n",
        result.name,
        result.cells.len(),
        result.trials
    );
    println!("{}", result.table().render());
    println!(
        "[{} cells in {:.1}s]",
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    );
    if let Some(path) = args.flag("out") {
        std::fs::write(path, result.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Print a line to stdout tolerating a closed downstream pipe: `serve
/// | head` must not panic in the summary prints after the stream ends.
fn println_safe(text: &str) {
    use std::io::Write as _;
    let _ = writeln!(std::io::stdout(), "{text}");
}

/// JSONL record sink for the serve commands: `--records FILE`, stdout
/// (default), or disabled (`--no-records`). Tracks write failures — a
/// truncated record stream must not exit 0 — while treating a closed
/// downstream pipe (`| head`) as a conventional end-of-stream.
struct RecordSink {
    out: Box<dyn std::io::Write>,
    streaming: bool,
    to_file: bool,
    err: Option<std::io::Error>,
}

impl RecordSink {
    fn from_args(args: &Args) -> anyhow::Result<Self> {
        let streaming = !args.switch("no-records");
        let to_file = matches!(args.flag("records"), Some(p) if p != "-");
        // Create the file only when streaming is on: `--no-records
        // --records FILE` must not truncate an existing record file.
        let out: Box<dyn std::io::Write> = if !streaming {
            Box::new(std::io::sink())
        } else {
            match args.flag("records") {
                Some(path) if path != "-" => {
                    Box::new(std::io::BufWriter::new(std::fs::File::create(path)?))
                }
                _ => Box::new(std::io::stdout()),
            }
        };
        Ok(Self {
            out,
            streaming,
            to_file,
            err: None,
        })
    }

    /// Whether the human summary must move to stderr (the JSONL records
    /// own stdout, which must stay machine-parseable end to end).
    fn summary_to_stderr(&self) -> bool {
        self.streaming && !self.to_file
    }

    fn write_line(&mut self, line: &str) {
        use std::io::Write as _;
        if !self.streaming {
            return;
        }
        if let Err(e) = writeln!(self.out, "{line}") {
            if e.kind() != std::io::ErrorKind::BrokenPipe {
                self.err = Some(e);
            }
            self.streaming = false;
        }
    }

    /// Flush and surface any write failure.
    fn finish(mut self) -> anyhow::Result<()> {
        use std::io::Write as _;
        if self.err.is_none() && self.streaming {
            if let Err(e) = self.out.flush() {
                if e.kind() != std::io::ErrorKind::BrokenPipe {
                    self.err = Some(e);
                }
            }
        }
        match self.err {
            Some(e) => {
                anyhow::bail!("failed writing job records ({e}); the JSONL stream is truncated")
            }
            None => Ok(()),
        }
    }
}

/// One streaming JSONL line: the job record plus its cell coordinates.
fn record_line(cell: &CellResult, r: &JobRecord) -> String {
    let mut j = r.to_json();
    j.set("cell", Json::Num(cell.index as f64));
    j.set("policy", Json::Str(cell.outcome.label.clone()));
    for (k, v) in &cell.axis_values {
        j.set(k, Json::Num(*v));
    }
    serve::json_line(&j)
}

/// `serve`: the online serving layer. Default runs the `serving`
/// catalog sweep (load factor × churn rate × policy), streaming one
/// JSON record per job; with `--scenario` it runs a single configurable
/// job stream instead.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.flag("scenario").is_some() {
        return cmd_serve_single(args);
    }
    let id = args.flag("figure").unwrap_or("serving");
    let mut spec = catalog::spec(
        id,
        args.usize_flag("trials", 20_000)?,
        args.u64_flag("seed", 2022)?,
    )?;
    anyhow::ensure!(
        spec.arrivals.is_some(),
        "catalog sweep '{id}' is not a serving sweep (no arrivals block); \
         run it with 'coded-coop sweep run --figure {id}'"
    );
    if args.flag("jobs").is_some() {
        let arr = spec.arrivals.as_mut().expect("checked above");
        arr.jobs = args.usize_flag("jobs", arr.jobs)?;
    } else {
        // No silent caps: the catalog bounds jobs per master (the cost
        // knob would otherwise explode on figure-sized --trials values).
        let arr_jobs = spec.arrivals.as_ref().expect("checked above").jobs;
        let requested = args.usize_flag("trials", 20_000)?;
        if arr_jobs < requested {
            eprintln!(
                "note: '{id}' caps --trials at {arr_jobs} jobs per master \
                 (pass --jobs to override)"
            );
        }
    }
    let mut sink = RecordSink::from_args(args)?;
    let summary: fn(&str) = if sink.summary_to_stderr() {
        |s| eprintln!("{s}")
    } else {
        println_safe
    };
    let t0 = std::time::Instant::now();
    // Incremental record streaming needs the sequential per-cell path;
    // without it the grid runs on the shared pool like `sweep run`.
    let result = if sink.streaming {
        experiment::run_serving_with(&spec, |c| {
            for r in &c.records {
                sink.write_line(&record_line(c, r));
            }
        })?
    } else {
        experiment::run_sweep(&spec, &SweepOptions::default())?
    };
    sink.finish()?;
    let mut t = Table::new(&[
        "cell",
        "axes",
        "policy",
        "jobs",
        "mean sojourn (ms)",
        "p99 (ms)",
        "starved",
    ]);
    for c in &result.cells {
        let axes = c
            .axis_values
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ");
        // Cell-level counters and the sketch p99 are computed once at
        // cell time and cover every job even when a record cap bounded
        // the ring — no re-collection from the records here.
        t.row(&[
            format!("{}", c.index),
            axes,
            c.outcome.label.clone(),
            format!("{}", c.jobs),
            format!("{:.3}", c.outcome.system.mean()),
            c.p99_ms
                .map(|p| format!("{p:.3}"))
                .unwrap_or_else(|| "-".into()),
            format!("{}", c.starved_jobs),
        ]);
    }
    summary(&format!(
        "\nserving sweep: {} ({} cells)\n\n{}",
        result.name,
        result.cells.len(),
        t.render()
    ));
    summary(&format!(
        "[{} cells in {:.1}s]",
        result.cells.len(),
        t0.elapsed().as_secs_f64()
    ));
    if let Some(path) = args.flag("out") {
        std::fs::write(path, result.to_json().to_string_pretty())?;
        summary(&format!("wrote {path}"));
    }
    Ok(())
}

/// `serve --scenario …`: one configurable job stream.
fn cmd_serve_single(args: &Args) -> anyhow::Result<()> {
    let s = parse_scenario(args)?;
    // --transport tcp: jobs run on the real socket runtime and churn is
    // OBSERVED from connection lifecycle instead of scripted — see the
    // serve::tcp module docs. thread/absent keeps the virtual stream.
    match args.flag("transport").unwrap_or("thread") {
        "thread" => {}
        "tcp" => return cmd_serve_tcp(args, &s),
        other => anyhow::bail!("--transport expects 'thread' or 'tcp', got '{other}'"),
    }
    let spec = parse_policy_spec(args)?;
    let mut cfg = serve::ServeConfig::new(spec);
    cfg.jobs = args.usize_flag("jobs", 50)?;
    cfg.load_factor = args.f64_flag("load-factor", 0.8)?;
    cfg.churn_rate = args.f64_flag("churn-rate", 0.0)?;
    cfg.churn_downtime = args.f64_flag("churn-downtime", 0.5)?;
    // --fault SPEC: churn synthesized from what the health layer would
    // observe under these faults, instead of the rate-based cycle.
    cfg.faults = parse_fault(args)?;
    cfg.process = ArrivalProcess::parse(args.flag("process").unwrap_or("poisson"))?;
    cfg.seed = args.u64_flag("seed", 2022)?;
    // Fleet-scale knobs: bounded record retention, event-core selection
    // (wheel default; heap = the parity oracle), and sharded per-master
    // streams on the process pool.
    cfg.record_cap = args.usize_flag("record-cap", 0)?;
    cfg.queue = serve::EventQueueKind::parse(args.flag("event-queue").unwrap_or("wheel"))?;
    let shard = args.switch("shard");
    // Open the record sink BEFORE the run: a bad --records path must
    // fail fast, not after the whole stream has been served.
    let mut sink = RecordSink::from_args(args)?;
    let summary: fn(&str) = if sink.summary_to_stderr() {
        |s| eprintln!("{s}")
    } else {
        println_safe
    };
    let out = if shard {
        serve::run_sharded(&s, &cfg)?
    } else {
        serve::run(&s, &cfg)?
    };
    for r in &out.records {
        sink.write_line(&serve::json_line(&r.to_json()));
    }
    sink.finish()?;
    summary(&format!("\nscenario: {}", s.name));
    summary(&format!(
        "plan:     {}  (t* = {:.3} ms, inter-arrival {:.3} ms)",
        out.label, out.t_est_ms, out.period_ms
    ));
    summary(&format!(
        "jobs: {} ({} starved) | mean sojourn {:.3} ms | p99 {} | replans {} | cache hits {} | sca iters {}",
        out.jobs,
        out.infeasible,
        out.system.mean(),
        out.p99_ms()
            .map(|p| format!("{p:.3} ms"))
            .unwrap_or_else(|| "-".into()),
        out.replans,
        out.cache_hits,
        out.sca_iters,
    ));
    Ok(())
}

/// `serve --transport tcp`: a short job sequence on the real socket
/// runtime, fleet admission driven by per-worker circuit breakers fed
/// from observed connection lifecycle (no [`ChurnScript`]).
///
/// [`ChurnScript`]: serve::ChurnScript
fn cmd_serve_tcp(args: &Args, s: &Scenario) -> anyhow::Result<()> {
    let mut cfg = serve::TcpServeConfig::new(parse_policy_spec(args)?);
    cfg.jobs = args.usize_flag("jobs", 3)?;
    cfg.cols = args.usize_flag("cols", 32)?;
    cfg.time_scale = args.f64_flag("time-scale", 2e-3)?;
    cfg.seed = args.u64_flag("seed", 2022)?;
    cfg.addrs = workers_at(args);
    cfg.auth = auth_token(args);
    cfg.fault = parse_fault(args)?;
    if args.switch("fast-health") {
        cfg.health = HealthConfig::fast();
    }
    // Always armed: lifecycle observation IS the point of this mode —
    // an unarmed run would render every disconnect invisible.
    cfg.health.armed = true;
    let mut sink = RecordSink::from_args(args)?;
    let summary: fn(&str) = if sink.summary_to_stderr() {
        |s| eprintln!("{s}")
    } else {
        println_safe
    };
    let out = serve::tcp::run_tcp(s, &cfg)?;
    for r in &out.records {
        sink.write_line(&serve::json_line(&r.to_json()));
    }
    sink.finish()?;
    summary(&format!("\nscenario: {} (serve over tcp)", s.name));
    summary(&format!(
        "jobs: {} ({} verified) | replans {} | cache hits {} | health events {} | redundancy-floor jobs {}",
        out.records.len(),
        out.records.iter().filter(|r| r.verified).count(),
        out.replans,
        out.cache_hits,
        out.health.len(),
        out.records.iter().filter(|r| r.redundancy_floor).count(),
    ));
    if !out.all_verified() {
        anyhow::bail!("serve over tcp: at least one job failed verification");
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    let m = args.usize_flag("masters", 2)?;
    let n = args.usize_flag("workers", 6)?;
    let rows = args.usize_flag("rows", 512)?;
    let cols = args.usize_flag("cols", 512)?;
    let seed = args.u64_flag("seed", 7)?;
    let scenario = Scenario::random(
        "e2e",
        m,
        n,
        rows as f64,
        AShift::Range(0.01, 0.05),
        2.0,
        CommModel::Stochastic,
        seed,
    );
    // Registry-resolved, so runtime-registered policies work here too.
    let spec = parse_policy_spec(args)?;
    let plan = spec.build(&scenario)?;

    // --fault SPEC (or the deprecated --flaky N): deterministic fault
    // injection, shared by both transports — thread workers resolve the
    // plan in-process, tcp workers receive it on their command line.
    let fault = parse_fault(args)?;
    // Armed explicitly (--fast-health tightens every window for quick
    // demos/CI) or implicitly by injecting a fault; a clean default run
    // keeps the PR-6 dispatch path untouched.
    let health = if args.switch("fast-health") {
        let mut h = HealthConfig::fast();
        h.armed = true;
        h
    } else {
        HealthConfig::default()
    };
    // --transport tcp: dispatch over worker processes; --workers-at
    // gives their endpoints, empty auto-spawns loopback processes.
    let transport = match args.flag("transport").unwrap_or("thread") {
        "thread" => coordinator::Transport::Thread,
        "tcp" => coordinator::Transport::Tcp(coordinator::TcpOptions {
            addrs: workers_at(args),
            auth: auth_token(args),
        }),
        other => anyhow::bail!("--transport expects 'thread' or 'tcp', got '{other}'"),
    };

    // PJRT by default; --native for environments without artifacts.
    // Fault injection lives in the FaultPlan now, so the backend choice
    // is independent of it (the encode leg is always reliable).
    let service;
    let backend = if args.switch("native") {
        Backend::Native
    } else {
        service = RuntimeService::start(&crate::runtime::default_artifact_dir())?;
        Backend::Pjrt(service.handle())
    };

    // --stream-jobs N: the queued-job stream (coordinator::run_stream) —
    // N tasks per master over ONE long-lived worker-thread set, the real
    // runtime's counterpart of the virtual-time serving layer.
    let stream_jobs = args.usize_flag("stream-jobs", 0)?;
    if stream_jobs > 0 {
        let outs = coordinator::run_stream(
            &scenario,
            &plan,
            &coordinator::StreamOptions {
                jobs: stream_jobs,
                period_ms: args.f64_flag("period-ms", plan.t_est())?,
                cols,
                time_scale: args.f64_flag("time-scale", 1e-4)?,
                backend,
                seed,
                verify: true,
                transport,
                fault,
                health,
            },
        )?;
        let mut t = Table::new(&[
            "job",
            "master",
            "arrival (ms)",
            "completion (ms)",
            "sojourn (ms)",
            "rows",
            "max rel err",
        ]);
        for o in &outs {
            t.row(&[
                format!("{}", o.job),
                format!("{}", o.master + 1),
                format!("{:.3}", o.arrival_ms),
                format!("{:.3}", o.completion_ms),
                format!("{:.3}", o.sojourn_ms()),
                format!("{}", o.rows_used),
                o.max_rel_err
                    .map(|e| format!("{e:.2e}"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        println!(
            "queued-job stream: {} jobs × {} masters on one worker-thread set\n{}",
            stream_jobs,
            scenario.n_masters(),
            t.render()
        );
        return Ok(());
    }

    let report = coordinator::run_plan(
        &scenario,
        &plan,
        &RunOptions {
            cols,
            time_scale: args.f64_flag("time-scale", 1e-4)?,
            backend,
            seed,
            verify: true,
            transport,
            fault,
            health,
        },
    )?;
    print_report(&report);
    // --out FILE: the full structured report (masters, events, health
    // timeline, the `verified` bit) for CI assertions and dashboards.
    if let Some(path) = args.flag("out") {
        std::fs::write(path, report.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Fault-injection flags: `--fault SPEC` (the [`FaultPlan`] DSL) and
/// the deprecated `--flaky N` (sugar for `flaky:all@N`); both present
/// concatenates. Validation is CLI-grade: `--flaky 1` explains WHY the
/// period must leave redundancy headroom instead of asserting.
fn parse_fault(args: &Args) -> anyhow::Result<Option<FaultPlan>> {
    let mut plan: Option<FaultPlan> = match args.flag("fault") {
        None => None,
        Some(s) => Some(FaultPlan::parse(s)?),
    };
    if args.flag("flaky").is_some() {
        let every = args.usize_flag("flaky", 0)?;
        eprintln!(
            "note: --flaky N is deprecated; use --fault flaky:all@{every} \
             (the SPEC syntax also injects crash/gray/spike/slow faults)"
        );
        let f = FaultPlan::flaky(every)?;
        plan = Some(match plan {
            None => f,
            Some(mut p) => {
                p.specs.extend(f.specs);
                p
            }
        });
    }
    Ok(plan)
}

/// `worker`: a standalone socket-mode worker process. Binds `--listen`
/// (port 0 picks a free port, announced as `LISTENING <addr>` on
/// stdout), then serves coordinator connections until killed — or
/// exactly one with `--once` (how auto-spawned loopback workers run).
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let listen = args.flag("listen").ok_or_else(|| {
        anyhow::anyhow!(
            "worker needs --listen ADDR (e.g. 127.0.0.1:7431, or 127.0.0.1:0 for a free port)"
        )
    })?;
    let server = net::WorkerServer::bind(listen)?;
    server.run(&net::WorkerConfig {
        backend: Backend::Native,
        once: args.switch("once"),
        fault: parse_fault(args)?,
        auth: auth_token(args),
    })
}

/// Shared report printer (also used by examples).
pub fn print_report(report: &coordinator::Report) {
    println!("plan: {}", report.label);
    let mut t = Table::new(&[
        "master",
        "completion (ms)",
        "planner t* (ms)",
        "rows recv",
        "rows cancelled",
        "max rel err",
        "encode wall (ms)",
    ]);
    for (m, mr) in report.masters.iter().enumerate() {
        t.row(&[
            format!("{}", m + 1),
            format!("{:.3}", mr.completion_ms),
            format!("{:.3}", mr.t_est_ms),
            format!("{}", mr.rows_used),
            format!("{}", mr.rows_cancelled),
            mr.max_rel_err
                .map(|e| format!("{e:.2e}"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.2}", mr.encode_wall_ms),
        ]);
    }
    println!("{}", t.render());
    println!(
        "system completion: {:.3} ms (virtual) | wall: {:.1} ms | verified: {}",
        report.system_completion_ms(),
        report.wall_ms,
        report.all_verified(1e-2),
    );
    if !report.health.is_empty() {
        println!("health events ({}):", report.health.len());
        for h in &report.health {
            println!(
                "  {:9.1} ms  w{}  {:10}  {}",
                h.at_ms,
                h.worker + 1,
                h.kind_label(),
                h.detail()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parse_flags_and_switches() {
        let a = args(&["figure", "fig4a", "--trials", "500", "--native", "--seed", "9"]);
        assert_eq!(a.positional, vec!["figure", "fig4a"]);
        assert_eq!(a.usize_flag("trials", 1).unwrap(), 500);
        assert_eq!(a.u64_flag("seed", 1).unwrap(), 9);
        assert!(a.switch("native"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn last_flag_wins() {
        let a = args(&["--seed", "1", "--seed", "2"]);
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 2);
    }

    #[test]
    fn bad_number_errors() {
        let a = args(&["--trials", "lots"]);
        assert!(a.usize_flag("trials", 1).is_err());
    }

    #[test]
    fn policy_and_method_parsers() {
        assert!(matches!(parse_policy("frac").unwrap(), Policy::Frac));
        assert!(matches!(parse_loads("sca").unwrap(), LoadMethod::Sca));
        assert!(matches!(
            parse_values("exact").unwrap(),
            ValueModel::Exact
        ));
        assert!(parse_policy("bogus").is_err());
        assert!(parse_loads("bogus").is_err());
    }

    #[test]
    fn scenario_parser_presets() {
        let a = args(&["--scenario", "large", "--seed", "3"]);
        let s = parse_scenario(&a).unwrap();
        assert_eq!(s.n_workers(), 50);
        let a = args(&["--scenario", "ec2"]);
        assert_eq!(parse_scenario(&a).unwrap().n_masters(), 4);
    }

    #[test]
    fn policy_spec_from_flags_resolves_registry_names() {
        let a = args(&["plan", "--policy", "frac", "--loads", "sca"]);
        let spec = parse_policy_spec(&a).unwrap();
        assert_eq!(spec.label().unwrap(), "Frac + SCA");
        let a = args(&["plan", "--policy", "not-a-policy"]);
        assert!(parse_policy_spec(&a).is_err());
    }

    #[test]
    fn help_lists_sweep_catalog() {
        let h = help_text();
        assert!(h.contains("sweep export"), "help misses sweep export");
        assert!(h.contains("sweep run"), "help misses sweep run");
        for id in ["fig6", "fig8_measured", "smoke", "serving", "overload"] {
            assert!(h.contains(id), "help missing catalog id {id}");
        }
        assert!(h.contains("coded-coop serve"), "help misses the serve command");
        assert!(h.contains("--load-factor"), "help misses serve knobs");
        assert!(h.contains("--record-cap"), "help misses the record cap");
        assert!(h.contains("--event-queue"), "help misses the event core knob");
        assert!(h.contains("burst"), "help misses the burst arrival process");
    }

    #[test]
    fn serve_record_lines_are_jsonl_with_cell_coordinates() {
        // Library-level check of what `coded-coop serve` streams.
        let mut spec = catalog::spec("serving", 4, 3).unwrap();
        spec.axes = vec![experiment::Axis::single("load_factor", &[0.7])];
        spec.policies.truncate(1);
        let mut lines = Vec::new();
        let result = experiment::run_serving_with(&spec, |c| {
            for r in &c.records {
                lines.push(record_line(c, r));
            }
        })
        .unwrap();
        assert_eq!(result.cells.len(), 1);
        assert_eq!(lines.len(), 2 * 4); // M = 2 masters × 4 jobs
        for line in &lines {
            assert!(!line.contains('\n'));
            let j = json::parse(line).unwrap();
            assert_eq!(j.get("cell").and_then(Json::as_usize), Some(0));
            assert_eq!(j.get("load_factor").and_then(Json::as_f64), Some(0.7));
            assert!(j.get("sojourn_ms").is_some());
            assert_eq!(j.get("feasible").and_then(Json::as_bool), Some(true));
            assert!(j.get("policy").and_then(Json::as_str).is_some());
        }
        // And the p99 helper orders sanely.
        let p99 = serve::p99_sojourn_ms(&result.cells[0].records).unwrap();
        assert!(p99 >= result.cells[0].outcome.system.mean());
    }

    #[test]
    fn sweep_export_then_run_roundtrips() {
        let dir = std::env::temp_dir().join("coded_coop_sweep_cli");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("smoke.json");
        // export (library path — same code cmd_sweep_export uses)
        let spec = catalog::spec("smoke", 500, 3).unwrap();
        std::fs::write(&path, spec.to_json().to_string_pretty()).unwrap();
        // run from the file, as `sweep run --spec` does
        let text = std::fs::read_to_string(&path).unwrap();
        let back = SweepSpec::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, spec);
        let result = experiment::run_sweep(
            &back,
            &SweepOptions {
                threads: 2,
                cell_streams: 2,
                fused: false,
            },
        )
        .unwrap();
        assert_eq!(result.cells.len(), 2);
        assert!(result.cells.iter().all(|c| c.outcome.system.mean() > 0.0));
    }

    #[test]
    fn fault_flags_validated() {
        // --flaky N is deprecated sugar for flaky:all@N…
        let p = parse_fault(&args(&["--flaky", "5"])).unwrap().unwrap();
        assert_eq!(p, FaultPlan::flaky(5).unwrap());
        assert!(parse_fault(&args(&[])).unwrap().is_none());
        // …whose validation explains the redundancy requirement.
        let e = parse_fault(&args(&["--flaky", "1"])).unwrap_err();
        assert!(e.to_string().contains("redundancy headroom"), "{e}");
        assert!(parse_fault(&args(&["--flaky", "nope"])).is_err());
        // The SPEC DSL parses…
        let p = parse_fault(&args(&["--fault", "crash:w3@50%,gray:w2@0%"]))
            .unwrap()
            .unwrap();
        assert_eq!(p.specs.len(), 2);
        assert!(parse_fault(&args(&["--fault", "meteor:w1@0%"])).is_err());
        // …and both flags concatenate into one plan.
        let p = parse_fault(&args(&["--fault", "crash:w1@50%", "--flaky", "7"]))
            .unwrap()
            .unwrap();
        assert_eq!(p.specs.len(), 2);
    }

    #[test]
    fn help_lists_worker_and_transport() {
        let h = help_text();
        assert!(h.contains("worker --listen"), "help misses the worker command");
        assert!(h.contains("--transport thread|tcp"), "help misses --transport");
        assert!(h.contains("--fault SPEC"), "help misses --fault");
        assert!(h.contains("crash:w3@50%"), "help misses the fault DSL examples");
        assert!(h.contains("--fast-health"), "help misses --fast-health");
        assert!(h.contains("--auth-token"), "help misses --auth-token");
        assert!(h.contains("CODED_COOP_AUTH"), "help misses the auth env var");
        assert!(
            h.contains("--transport tcp"),
            "help misses serve's tcp transport"
        );
    }

    #[test]
    fn help_lists_registered_policies() {
        let h = help_text();
        for name in ["uncoded", "coded", "dedi-iter", "frac", "optimal", "sca"] {
            assert!(h.contains(name), "help missing {name}");
        }
        // The pin-only internal allocator is not advertised.
        assert!(!h.contains("uncoded-split"), "help leaks internal allocator");
    }
}
