//! Command-line interface (hand-rolled — no `clap` offline).
//!
//! ```text
//! coded-coop figure <fig2|fig3|fig4a|fig4b|fig5|fig6|fig7|fig8|all>
//!            [--trials N] [--seed S] [--out DIR] [--fit-samples N]
//! coded-coop plan   --scenario <small|large|ec2|FILE.json>
//!            [--policy P] [--loads markov|exact|sca]
//!            [--values markov|exact] [--gamma-ratio R] [--seed S]
//! coded-coop e2e    [--masters M] [--workers N] [--rows L] [--cols S]
//!            [--policy P] [--seed S] [--native] [--time-scale X]
//! coded-coop version | help
//! ```

use crate::assign::ValueModel;
use crate::config::{AShift, CommModel, Scenario};
use crate::coordinator::{self, Backend, CoordinatorConfig};
use crate::figures::{self, FigureOptions};
use crate::plan::{self, LoadMethod, PlanSpec, Policy};
use crate::runtime::RuntimeService;
use crate::util::table::Table;

/// Parsed flag map: `--key value` pairs + positional arguments.
pub struct Args {
    pub positional: Vec<String>,
    flags: Vec<(String, String)>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut positional = Vec::new();
        let mut flags = Vec::new();
        let mut switches = Vec::new();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        flags.push((key.to_string(), it.next().unwrap()));
                    }
                    _ => switches.push(key.to_string()),
                }
            } else {
                positional.push(a);
            }
        }
        Ok(Self {
            positional,
            flags,
            switches,
        })
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn switch(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key)
    }

    pub fn usize_flag(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_flag(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn u64_flag(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flag(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }
}

const HELP: &str = "\
coded-coop — Coded Computation across Shared Heterogeneous Workers (TSP'22)

USAGE:
  coded-coop figure <id|all> [--trials N] [--seed S] [--out DIR] [--fit-samples N]
  coded-coop ablation <redundancy|multimsg|straggler|sca_step|all> [--trials N]
  coded-coop plan --scenario <small|large|ec2|FILE.json> [--policy P]
                  [--loads markov|exact|sca] [--values markov|exact]
                  [--gamma-ratio R] [--seed S]
  coded-coop e2e  [--masters M] [--workers N] [--rows L] [--cols S]
                  [--policy P] [--seed S] [--native] [--time-scale X]
  coded-coop version | help

figures: fig2 fig3 fig4a fig4b fig5 fig6 fig7 fig8 (see DESIGN.md §4)
policies: uncoded coded dedi-simple dedi-iter frac optimal
";

pub fn parse_policy(s: &str) -> anyhow::Result<Policy> {
    Ok(match s {
        "uncoded" => Policy::UncodedUniform,
        "coded" => Policy::CodedUniform,
        "dedi-simple" => Policy::DediSimple,
        "dedi-iter" => Policy::DediIter,
        "frac" => Policy::Frac,
        "optimal" => Policy::FracOptimal,
        other => anyhow::bail!("unknown policy '{other}'"),
    })
}

pub fn parse_loads(s: &str) -> anyhow::Result<LoadMethod> {
    Ok(match s {
        "markov" => LoadMethod::Markov,
        "exact" => LoadMethod::Exact,
        "sca" => LoadMethod::Sca,
        other => anyhow::bail!("unknown load method '{other}'"),
    })
}

pub fn parse_values(s: &str) -> anyhow::Result<ValueModel> {
    Ok(match s {
        "markov" => ValueModel::Markov,
        "exact" => ValueModel::Exact,
        other => anyhow::bail!("unknown value model '{other}'"),
    })
}

pub fn parse_scenario(a: &Args) -> anyhow::Result<Scenario> {
    let seed = a.u64_flag("seed", 2022)?;
    let ratio = a.f64_flag("gamma-ratio", 2.0)?;
    let comm = if a.switch("comp-dominant") {
        CommModel::CompDominant
    } else {
        CommModel::Stochastic
    };
    match a.flag("scenario").unwrap_or("small") {
        "small" => Ok(Scenario::small_scale(seed, ratio, comm)),
        "large" => Ok(Scenario::large_scale(seed, ratio, comm)),
        "ec2" => Ok(Scenario::ec2(40, 10, a.switch("stragglers"))),
        path => Scenario::from_file(path),
    }
}

/// Entry point for the `coded-coop` binary.
pub fn run() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.positional.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args),
        Some("ablation") => cmd_ablation(&args),
        Some("plan") => cmd_plan(&args),
        Some("e2e") => cmd_e2e(&args),
        Some("version") => {
            println!("coded-coop {}", crate::VERSION);
            Ok(())
        }
        Some("help") | None => {
            print!("{HELP}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown command '{other}'\n{HELP}"),
    }
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let opts = FigureOptions {
        trials: args.usize_flag("trials", 100_000)?,
        seed: args.u64_flag("seed", 2022)?,
        fit_samples: args.usize_flag("fit-samples", 200_000)?,
        threads: args.usize_flag("threads", 0)?,
    };
    let ids: Vec<&str> = if id == "all" {
        figures::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let t0 = std::time::Instant::now();
        let fig = figures::run(id, &opts)?;
        println!("{}", fig.render());
        println!("[{} regenerated in {:.1}s]\n", id, t0.elapsed().as_secs_f64());
        if let Some(dir) = args.flag("out") {
            fig.save(dir)?;
            println!("saved {dir}/{id}.json and {dir}/{id}.txt\n");
        }
    }
    Ok(())
}

fn cmd_ablation(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let opts = FigureOptions {
        trials: args.usize_flag("trials", 30_000)?,
        seed: args.u64_flag("seed", 2022)?,
        fit_samples: args.usize_flag("fit-samples", 50_000)?,
        threads: args.usize_flag("threads", 0)?,
    };
    let ids: Vec<&str> = if id == "all" {
        figures::ablations::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        let fig = figures::ablations::run(id, &opts)?;
        println!("{}", fig.render());
        if let Some(dir) = args.flag("out") {
            fig.save(dir)?;
        }
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let s = parse_scenario(args)?;
    let spec = PlanSpec {
        policy: parse_policy(args.flag("policy").unwrap_or("dedi-iter"))?,
        values: parse_values(args.flag("values").unwrap_or("markov"))?,
        loads: parse_loads(args.flag("loads").unwrap_or("markov"))?,
    };
    let p = plan::build(&s, &spec);
    println!("scenario: {}", s.name);
    println!("plan:     {}  (t* = {:.3} ms)\n", p.label, p.t_est());
    for (m, mp) in p.masters.iter().enumerate() {
        let mut t = Table::new(&["node", "load l", "k", "b"]);
        for e in &mp.entries {
            let node = if e.node == 0 {
                "local".to_string()
            } else {
                format!("w{}", e.node)
            };
            t.row(&[
                node,
                format!("{:.1}", e.load),
                format!("{:.3}", e.k),
                format!("{:.3}", e.b),
            ]);
        }
        println!(
            "master {} (L = {}, t*_m = {:.3} ms, overhead {:.2}×):\n{}",
            m + 1,
            mp.l_rows,
            mp.t_est,
            mp.total_load() / mp.l_rows,
            t.render()
        );
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> anyhow::Result<()> {
    let m = args.usize_flag("masters", 2)?;
    let n = args.usize_flag("workers", 6)?;
    let rows = args.usize_flag("rows", 512)?;
    let cols = args.usize_flag("cols", 512)?;
    let seed = args.u64_flag("seed", 7)?;
    let scenario = Scenario::random(
        "e2e",
        m,
        n,
        rows as f64,
        AShift::Range(0.01, 0.05),
        2.0,
        CommModel::Stochastic,
        seed,
    );
    let spec = PlanSpec {
        policy: parse_policy(args.flag("policy").unwrap_or("dedi-iter"))?,
        values: ValueModel::Markov,
        loads: parse_loads(args.flag("loads").unwrap_or("markov"))?,
    };

    // PJRT by default; --native for environments without artifacts.
    let service;
    let backend = if args.switch("native") {
        Backend::Native
    } else {
        service = RuntimeService::start(&crate::runtime::default_artifact_dir())?;
        Backend::Pjrt(service.handle())
    };

    let cfg = CoordinatorConfig {
        scenario,
        spec,
        cols,
        time_scale: args.f64_flag("time-scale", 1e-4)?,
        backend,
        seed,
        verify: true,
    };
    let report = coordinator::run(&cfg)?;
    print_report(&report);
    Ok(())
}

/// Shared report printer (also used by examples).
pub fn print_report(report: &coordinator::Report) {
    println!("plan: {}", report.label);
    let mut t = Table::new(&[
        "master",
        "completion (ms)",
        "planner t* (ms)",
        "rows recv",
        "rows cancelled",
        "max rel err",
        "encode wall (ms)",
    ]);
    for (m, mr) in report.masters.iter().enumerate() {
        t.row(&[
            format!("{}", m + 1),
            format!("{:.3}", mr.completion_ms),
            format!("{:.3}", mr.t_est_ms),
            format!("{}", mr.rows_used),
            format!("{}", mr.rows_cancelled),
            mr.max_rel_err
                .map(|e| format!("{e:.2e}"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.2}", mr.encode_wall_ms),
        ]);
    }
    println!("{}", t.render());
    println!(
        "system completion: {:.3} ms (virtual) | wall: {:.1} ms | verified: {}",
        report.system_completion_ms(),
        report.wall_ms,
        report.all_verified(1e-2),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parse_flags_and_switches() {
        let a = args(&["figure", "fig4a", "--trials", "500", "--native", "--seed", "9"]);
        assert_eq!(a.positional, vec!["figure", "fig4a"]);
        assert_eq!(a.usize_flag("trials", 1).unwrap(), 500);
        assert_eq!(a.u64_flag("seed", 1).unwrap(), 9);
        assert!(a.switch("native"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn last_flag_wins() {
        let a = args(&["--seed", "1", "--seed", "2"]);
        assert_eq!(a.u64_flag("seed", 0).unwrap(), 2);
    }

    #[test]
    fn bad_number_errors() {
        let a = args(&["--trials", "lots"]);
        assert!(a.usize_flag("trials", 1).is_err());
    }

    #[test]
    fn policy_and_method_parsers() {
        assert!(matches!(parse_policy("frac").unwrap(), Policy::Frac));
        assert!(matches!(parse_loads("sca").unwrap(), LoadMethod::Sca));
        assert!(matches!(
            parse_values("exact").unwrap(),
            ValueModel::Exact
        ));
        assert!(parse_policy("bogus").is_err());
        assert!(parse_loads("bogus").is_err());
    }

    #[test]
    fn scenario_parser_presets() {
        let a = args(&["--scenario", "large", "--seed", "3"]);
        let s = parse_scenario(&a).unwrap();
        assert_eq!(s.n_workers(), 50);
        let a = args(&["--scenario", "ec2"]);
        assert_eq!(parse_scenario(&a).unwrap().n_masters(), 4);
    }
}
