//! Open planner API: strategy traits, the string-keyed policy
//! [`registry`], and the serializable [`PolicySpec`].
//!
//! The paper contributes a *family* of algorithms (Algs. 1–4, Thms. 1–3)
//! that all share one pipeline:
//!
//! ```text
//! Scenario ──(Assigner)──▶ Assignment ──(LoadAllocator)──▶ Plan
//! ```
//!
//! This module makes both seams open traits so a new strategy — e.g. a
//! group-wise allocation (arXiv:1904.07490) or stream-style pipelining
//! (arXiv:2103.01921) — plugs in by implementing [`Assigner`] and/or
//! [`LoadAllocator`] in one module and registering it under a name:
//!
//! * [`Assigner`] — which nodes serve which master, with what resource
//!   shares (§III-C, §IV-B);
//! * [`LoadAllocator`] — how many coded rows each serving node gets and
//!   the predicted delay `t_m*` (§III-A/B/D);
//! * [`registry`] — name → strategy resolution shared by the CLI, JSON
//!   configs and the figure harnesses; [`registry::register_assigner`] /
//!   [`registry::register_allocator`] extend it at runtime with **zero**
//!   edits to `plan::build`;
//! * [`PolicySpec`] — the serializable (policy, values, loads) triple;
//!   [`builtin`] holds the paper's implementations.
//!
//! The legacy closed enums (`plan::Policy`, `plan::LoadMethod`,
//! `plan::PlanSpec`) remain as thin shims over this module.

pub mod builtin;
pub mod registry;

use std::sync::Arc;

use crate::alloc::Allocation;
use crate::assign::{Dedicated, Fractional, ValueModel};
use crate::config::Scenario;
use crate::plan::{self, Plan};
use crate::util::json::Json;

/// Output of an [`Assigner`]: which nodes serve each master, and with
/// what resource shares.
#[derive(Clone, Debug)]
pub enum Assignment {
    /// Whole workers per master (`k = b = 1`).
    Dedicated {
        d: Dedicated,
        /// Include node 0 (the master's local processor) in every
        /// master's serving set.
        include_local: bool,
        /// The plan carries no coding redundancy: ALL sub-tasks must
        /// finish (§V benchmark 1).
        uncoded: bool,
    },
    /// Per-(master, worker) fractional shares (§IV); the local node is
    /// always included with full shares.
    Fractional(Fractional),
}

impl Assignment {
    /// Serving-node ids (0 = local, `w + 1` = worker `w`) and `(k, b)`
    /// shares for master `m`, in plan order.
    pub fn nodes_of(&self, s: &Scenario, m: usize) -> (Vec<usize>, Vec<(f64, f64)>) {
        match self {
            Assignment::Dedicated {
                d, include_local, ..
            } => {
                let mut nodes = Vec::new();
                if *include_local {
                    nodes.push(0usize);
                }
                nodes.extend(d.workers_of(m).iter().map(|&w| w + 1));
                let shares = vec![(1.0, 1.0); nodes.len()];
                (nodes, shares)
            }
            Assignment::Fractional(f) => {
                let mut nodes = vec![0usize];
                let mut shares = vec![(1.0, 1.0)];
                for w in 0..s.n_workers() {
                    // A worker participates only with BOTH shares positive
                    // (k, b, l all-zero-or-all-nonzero, §IV-A).
                    if f.k[m][w] > 1e-12 && f.b[m][w] > 1e-12 {
                        nodes.push(w + 1);
                        shares.push((f.k[m][w], f.b[m][w]));
                    }
                }
                (nodes, shares)
            }
        }
    }

    /// Whether plans built from this assignment are uncoded.
    pub fn uncoded(&self) -> bool {
        matches!(self, Assignment::Dedicated { uncoded: true, .. })
    }
}

/// Worker-assignment strategy: `Scenario` → [`Assignment`].
pub trait Assigner: Send + Sync {
    /// Legend label fragment ("Dedi, iter", "Uncoded", …).
    fn label(&self) -> String;

    /// Benchmarks pin their load allocator (e.g. "Coded \[5\]" always
    /// uses the Theorem-2 loads, "Uncoded" its equal split); `None`
    /// honors the requested allocator.
    fn pinned_allocator(&self) -> Option<&'static str> {
        None
    }

    /// Decide the serving sets / resource shares.
    fn assign(&self, s: &Scenario) -> Assignment;
}

/// Load-allocation strategy: assignment → per-node loads + `t_m*`.
pub trait LoadAllocator: Send + Sync {
    /// Label suffix appended to non-benchmark policies (" + SCA").
    fn label_suffix(&self) -> &'static str {
        ""
    }

    /// Split master `m`'s `L_m` rows over `nodes` (ids; 0 = local) with
    /// resource shares `shares[i] = (k, b)`.
    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        shares: &[(f64, f64)],
    ) -> Allocation;
}

/// A fully resolved strategy pair, ready to build [`Plan`]s.
#[derive(Clone)]
pub struct ResolvedPolicy {
    /// Registry key of the assigner.
    pub policy: String,
    /// Registry key of the allocator actually used (post-pinning).
    pub loads: String,
    pub assigner: Arc<dyn Assigner>,
    pub allocator: Arc<dyn LoadAllocator>,
}

impl ResolvedPolicy {
    /// Legend label ("Dedi, iter + SCA", …).
    pub fn label(&self) -> String {
        format!(
            "{}{}",
            self.assigner.label(),
            self.allocator.label_suffix()
        )
    }

    /// Build the complete deployment decision.
    pub fn build(&self, s: &Scenario) -> Plan {
        plan::build_with(
            s,
            self.assigner.as_ref(),
            self.allocator.as_ref(),
            &self.label(),
        )
    }
}

/// Serializable planning request: registry names + the node-value model.
///
/// This is the open-world counterpart of the legacy `plan::PlanSpec`
/// (closed enums): `policy` and `loads` are registry keys, so specs can
/// name strategies registered by downstream code.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PolicySpec {
    /// Assigner registry key ("dedi-iter", "frac", …).
    pub policy: String,
    /// Node-value model driving the assignment search.
    pub values: ValueModel,
    /// Allocator registry key ("markov", "exact", "sca").
    pub loads: String,
}

impl PolicySpec {
    pub fn new(policy: &str, values: ValueModel, loads: &str) -> Self {
        Self {
            policy: policy.to_string(),
            values,
            loads: loads.to_string(),
        }
    }

    /// Resolve against the registry.
    pub fn resolve(&self) -> anyhow::Result<ResolvedPolicy> {
        registry::resolve(&self.policy, self.values, &self.loads)
    }

    /// Legend label, as the resolved strategy would report it.
    pub fn label(&self) -> anyhow::Result<String> {
        Ok(self.resolve()?.label())
    }

    /// Resolve + build in one step.
    pub fn build(&self, s: &Scenario) -> anyhow::Result<Plan> {
        Ok(self.resolve()?.build(s))
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("policy", Json::Str(self.policy.clone()));
        j.set("values", Json::Str(value_model_name(self.values).into()));
        j.set("loads", Json::Str(self.loads.clone()));
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let field = |k: &str| -> anyhow::Result<&str> {
            j.get(k)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("spec missing string field '{k}'"))
        };
        Ok(Self {
            policy: field("policy")?.to_string(),
            values: parse_value_model(field("values")?)?,
            loads: field("loads")?.to_string(),
        })
    }
}

/// Registry/JSON name of a [`ValueModel`].
pub fn value_model_name(v: ValueModel) -> &'static str {
    match v {
        ValueModel::Markov => "markov",
        ValueModel::Exact => "exact",
    }
}

/// Parse a [`ValueModel`] name.
pub fn parse_value_model(s: &str) -> anyhow::Result<ValueModel> {
    match s {
        "markov" => Ok(ValueModel::Markov),
        "exact" => Ok(ValueModel::Exact),
        other => anyhow::bail!("unknown value model '{other}' (markov|exact)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CommModel;

    #[test]
    fn policy_spec_json_roundtrip() {
        let spec = PolicySpec::new("dedi-iter", ValueModel::Exact, "sca");
        let back = PolicySpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        assert!(PolicySpec::from_json(&Json::obj()).is_err());
    }

    #[test]
    fn resolve_unknown_names_fails_cleanly() {
        assert!(PolicySpec::new("nope", ValueModel::Markov, "markov")
            .resolve()
            .is_err());
        assert!(PolicySpec::new("dedi-iter", ValueModel::Markov, "nope")
            .resolve()
            .is_err());
    }

    #[test]
    fn labels_match_paper_legends() {
        // Literal expectations (the §V legend strings the figure tests
        // key off) — NOT derived through the same code path they guard.
        let table = [
            ("uncoded", "markov", "Uncoded"),
            ("uncoded", "sca", "Uncoded"), // benchmark pins ⇒ no suffix
            ("coded", "markov", "Coded [5]"),
            ("coded", "sca", "Coded [5]"),
            ("dedi-simple", "markov", "Dedi, simple"),
            ("dedi-simple", "sca", "Dedi, simple + SCA"),
            ("dedi-iter", "exact", "Dedi, iter"),
            ("dedi-iter", "sca", "Dedi, iter + SCA"),
            ("frac", "markov", "Frac"),
            ("frac", "sca", "Frac + SCA"),
            ("optimal", "sca", "Optimal + SCA"),
            ("optimal", "markov", "Optimal"),
        ];
        for (name, lname, want) in table {
            let open = PolicySpec::new(name, ValueModel::Markov, lname);
            assert_eq!(open.label().unwrap(), want, "{name}/{lname}");
        }
    }

    #[test]
    fn benchmarks_pin_their_allocator() {
        // "Uncoded"/"Coded [5]" ignore the requested loads, exactly like
        // the legacy match arms did.
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        for loads in ["markov", "exact", "sca"] {
            let p = PolicySpec::new("coded", ValueModel::Markov, loads)
                .build(&s)
                .unwrap();
            assert_eq!(p.label, "Coded [5]");
            let q = PolicySpec::new("uncoded", ValueModel::Markov, loads)
                .build(&s)
                .unwrap();
            assert!(q.uncoded);
            assert_eq!(q.label, "Uncoded");
        }
    }
}
