//! The paper's strategies as [`Assigner`] / [`LoadAllocator`]
//! implementations (registered under the names in [`super::registry`]).
//!
//! | registry key | paper | implementation |
//! |---|---|---|
//! | `uncoded` | §V benchmark 1 | uniform split, no coding, no local |
//! | `coded` | §V benchmark 2 (\[5\]) | uniform workers, Thm-2 loads |
//! | `dedi-simple` | Algorithm 2 | largest-value-first greedy |
//! | `dedi-iter` | Algorithm 1 | iterated greedy |
//! | `frac` | Algorithm 4 | resource balancing from an Alg-1 start |
//! | `optimal` | §V benchmark 3 | λ-sweep grid optimum (M = 2) |
//! | `markov` (loads) | Theorem 1 | closed form on θ |
//! | `exact` (loads) | Theorem 2 | computation-dominant closed form |
//! | `sca` (loads) | Algorithm 3 | Thm-1 start + SCA enhancement |

use super::{Assigner, Assignment, LoadAllocator};
use crate::alloc::{comp_dominant, markov, sca, Allocation, EffLink};
use crate::assign::{
    dedicated_iter, dedicated_simple, fractional, optimal, uniform, ValueMatrix,
    ValueModel,
};
use crate::config::Scenario;

// ---------------------------------------------------------------------------
// Assigners
// ---------------------------------------------------------------------------

/// §V benchmark 1: uniform workers, equal split, no coding, no local.
pub struct UncodedUniformAssigner;

impl Assigner for UncodedUniformAssigner {
    fn label(&self) -> String {
        "Uncoded".into()
    }

    fn pinned_allocator(&self) -> Option<&'static str> {
        Some("uncoded-split")
    }

    fn assign(&self, s: &Scenario) -> Assignment {
        Assignment::Dedicated {
            d: uniform::assign(s.n_masters(), s.n_workers()),
            include_local: false,
            uncoded: true,
        }
    }
}

/// §V benchmark 2: uniform workers, Theorem-2 loads (\[5\]).
pub struct CodedUniformAssigner;

impl Assigner for CodedUniformAssigner {
    fn label(&self) -> String {
        "Coded [5]".into()
    }

    fn pinned_allocator(&self) -> Option<&'static str> {
        Some("exact")
    }

    fn assign(&self, s: &Scenario) -> Assignment {
        Assignment::Dedicated {
            d: uniform::assign(s.n_masters(), s.n_workers()),
            include_local: true,
            uncoded: false,
        }
    }
}

/// Algorithm 2: largest-value-first greedy dedicated assignment.
pub struct DediSimpleAssigner {
    pub values: ValueModel,
}

impl Assigner for DediSimpleAssigner {
    fn label(&self) -> String {
        "Dedi, simple".into()
    }

    fn assign(&self, s: &Scenario) -> Assignment {
        let vm = ValueMatrix::new(s, self.values);
        Assignment::Dedicated {
            d: dedicated_simple::assign(&vm),
            include_local: true,
            uncoded: false,
        }
    }
}

/// Algorithm 1: iterated greedy dedicated assignment.
pub struct DediIterAssigner {
    pub values: ValueModel,
}

impl Assigner for DediIterAssigner {
    fn label(&self) -> String {
        "Dedi, iter".into()
    }

    fn assign(&self, s: &Scenario) -> Assignment {
        let vm = ValueMatrix::new(s, self.values);
        Assignment::Dedicated {
            d: dedicated_iter::assign(&vm, &Default::default()),
            include_local: true,
            uncoded: false,
        }
    }
}

/// Algorithm 4: fractional assignment from an Algorithm-1 start.
pub struct FracAssigner {
    pub values: ValueModel,
}

impl Assigner for FracAssigner {
    fn label(&self) -> String {
        "Frac".into()
    }

    fn assign(&self, s: &Scenario) -> Assignment {
        let vm = ValueMatrix::new(s, self.values);
        let d = dedicated_iter::assign(&vm, &Default::default());
        Assignment::Fractional(fractional::assign(s, &d, &Default::default()))
    }
}

/// λ-sweep grid optimum (M = 2 only; §V benchmark 3).
pub struct FracOptimalAssigner;

impl Assigner for FracOptimalAssigner {
    fn label(&self) -> String {
        "Optimal".into()
    }

    fn assign(&self, s: &Scenario) -> Assignment {
        Assignment::Fractional(optimal::assign(s, &Default::default()))
    }
}

// ---------------------------------------------------------------------------
// Load allocators
// ---------------------------------------------------------------------------

/// Theorem 1 closed form on θ (the "Approx" of Figs. 2–3).
///
/// Distribution-free (Remark 1): consumes the family-aware moment
/// interface [`Scenario::theta`] — the Markov bound holds for EVERY
/// delay family with a finite mean (all constructible ones), so this
/// allocator is exact-assumption-clean under heavy tails and traces.
pub struct MarkovAllocator;

impl LoadAllocator for MarkovAllocator {
    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        shares: &[(f64, f64)],
    ) -> Allocation {
        let thetas: Vec<f64> = nodes
            .iter()
            .zip(shares)
            .map(|(&n, &(k, b))| s.theta(m, n, k, b))
            .collect();
        markov::allocate(&thetas, s.l_rows(m))
    }
}

/// Theorem 2 closed form on (a, u) — computation-dominant exact.
///
/// Exact only for shifted-exponential computation delays; for other
/// delay families it allocates on the fitted `(a, u)` surrogate (the
/// paper's own plan-with-the-fit stance — DESIGN.md §Delay-model
/// layer documents which bounds hold where).
pub struct ExactAllocator;

impl LoadAllocator for ExactAllocator {
    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        shares: &[(f64, f64)],
    ) -> Allocation {
        let params: Vec<comp_dominant::CompParams> = nodes
            .iter()
            .zip(shares)
            .map(|(&n, &(k, _))| {
                let p = s.link(m, n);
                comp_dominant::CompParams {
                    a: p.a / k,
                    u: k * p.u,
                }
            })
            .collect();
        comp_dominant::allocate(&params, s.l_rows(m))
    }
}

/// Theorem 1 start + Algorithm 3 SCA enhancement.
///
/// The SCA subproblems need the closed-form hypoexponential CDF
/// (eq. 3), so the enhancement runs on the shifted-exponential fit; for
/// other delay families the refined loads are a surrogate enhancement
/// of the (family-aware) Markov start — conservative under mean-matched
/// heavy tails, documented in DESIGN.md §Delay-model layer.
pub struct ScaAllocator;

impl LoadAllocator for ScaAllocator {
    fn label_suffix(&self) -> &'static str {
        " + SCA"
    }

    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        shares: &[(f64, f64)],
    ) -> Allocation {
        let links: Vec<EffLink> = nodes
            .iter()
            .zip(shares)
            .map(|(&n, &(k, b))| EffLink::fractional(&s.link(m, n), k, b))
            .collect();
        sca::allocate(&links, s.l_rows(m), &Default::default())
    }
}

/// Benchmark-1 equal split: `L_m / |Ω_m|` rows per worker, no
/// redundancy. Without coding the best delay estimate is the slowest
/// node's mean.
pub struct UncodedSplitAllocator;

impl LoadAllocator for UncodedSplitAllocator {
    fn allocate(
        &self,
        s: &Scenario,
        m: usize,
        nodes: &[usize],
        _shares: &[(f64, f64)],
    ) -> Allocation {
        let share = s.l_rows(m) / nodes.len() as f64;
        let t_star = nodes
            .iter()
            .map(|&n| share * s.theta(m, n, 1.0, 1.0))
            .fold(0.0, f64::max);
        Allocation {
            loads: vec![share; nodes.len()],
            t_star,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CommModel;

    #[test]
    fn assignments_cover_all_workers() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        for assigner in [
            &UncodedUniformAssigner as &dyn Assigner,
            &CodedUniformAssigner,
            &DediSimpleAssigner {
                values: ValueModel::Markov,
            },
            &DediIterAssigner {
                values: ValueModel::Markov,
            },
        ] {
            match assigner.assign(&s) {
                Assignment::Dedicated { d, .. } => {
                    assert_eq!(d.owner.len(), s.n_workers(), "{}", assigner.label());
                }
                Assignment::Fractional(_) => panic!("expected dedicated"),
            }
        }
    }

    #[test]
    fn markov_allocator_consumes_family_moments() {
        // Identical scenarios except the workers' delay family: a trace
        // with mean ≫ the fitted (a, u) mean must pull the Markov
        // allocation toward the (still shifted-exp) local node and
        // raise the predicted t* — the moment interface at work.
        use crate::config::Transform;
        use crate::model::dist::{FamilyKind, TraceDist};
        let base = Scenario::small_scale(9, 2.0, CommModel::Stochastic);
        let mut slow = base.clone();
        let id = slow.add_trace(TraceDist::from_samples("slow", vec![4.9, 5.0, 5.1]).unwrap());
        let slow = slow.transformed(&[Transform::Family(FamilyKind::Trace { id })]);
        let nodes: Vec<usize> = (0..=base.n_workers()).collect();
        let shares = vec![(1.0, 1.0); nodes.len()];
        let fast = MarkovAllocator.allocate(&base, 0, &nodes, &shares);
        let slowa = MarkovAllocator.allocate(&slow, 0, &nodes, &shares);
        assert!(slowa.t_star > fast.t_star, "{} vs {}", slowa.t_star, fast.t_star);
        let rel = |a: &Allocation| a.loads[0] / a.total_load();
        assert!(rel(&slowa) > rel(&fast), "local share should grow");
        // Mean-matched parametric families leave the allocation intact
        // (same first moment ⇒ same Theorem-1 closed form).
        let wb = base
            .clone()
            .transformed(&[Transform::Family(FamilyKind::Weibull { shape: 0.6 })]);
        let wba = MarkovAllocator.allocate(&wb, 0, &nodes, &shares);
        assert!((wba.t_star - fast.t_star).abs() / fast.t_star < 1e-9);
        for (x, y) in wba.loads.iter().zip(&fast.loads) {
            assert!((x - y).abs() / y.max(1e-12) < 1e-9);
        }
    }

    #[test]
    fn uncoded_split_matches_hand_formula() {
        let s = Scenario::small_scale(2, 2.0, CommModel::Stochastic);
        let nodes = [1usize, 2, 3];
        let shares = [(1.0, 1.0); 3];
        let a = UncodedSplitAllocator.allocate(&s, 0, &nodes, &shares);
        let share = s.l_rows(0) / 3.0;
        assert!(a.loads.iter().all(|&l| (l - share).abs() < 1e-9));
        let worst = nodes
            .iter()
            .map(|&n| share * s.link(0, n).theta())
            .fold(0.0, f64::max);
        assert!((a.t_star - worst).abs() < 1e-9);
    }
}
