//! String-keyed strategy registry: the single place CLI flags, JSON
//! configs and figure harnesses resolve policy names.
//!
//! Built-ins (see [`super::builtin`]) are installed on first use; new
//! strategies register at runtime:
//!
//! ```text
//! registry::register_assigner("my-policy", |values| Arc::new(MyAssigner { values }));
//! registry::register_allocator("my-loads", || Arc::new(MyAllocator));
//! PolicySpec::new("my-policy", ValueModel::Markov, "my-loads").build(&scenario)?;
//! ```
//!
//! Later registrations shadow earlier ones (including built-ins), so a
//! deployment can override a stock strategy without forking the crate.
//! `plan::build` has no policy `match` left — adding a strategy touches
//! only the new module plus one `register_*` call.

use std::sync::{Arc, Mutex, OnceLock};

use super::builtin;
use super::{Assigner, LoadAllocator, ResolvedPolicy};
use crate::assign::ValueModel;

/// Constructs an assigner for a given node-value model.
pub type AssignerFactory = Arc<dyn Fn(ValueModel) -> Arc<dyn Assigner> + Send + Sync>;

/// Constructs a load allocator.
pub type AllocatorFactory = Arc<dyn Fn() -> Arc<dyn LoadAllocator> + Send + Sync>;

struct Registry {
    /// Insertion-ordered; lookups scan from the END so later
    /// registrations shadow earlier ones.
    assigners: Vec<(String, AssignerFactory)>,
    allocators: Vec<(String, AllocatorFactory)>,
}

impl Registry {
    fn builtins() -> Self {
        let mut r = Registry {
            assigners: Vec::new(),
            allocators: Vec::new(),
        };
        fn assigner<A: Assigner + 'static>(a: A) -> Arc<dyn Assigner> {
            Arc::new(a)
        }
        fn allocator<L: LoadAllocator + 'static>(l: L) -> Arc<dyn LoadAllocator> {
            Arc::new(l)
        }
        r.assigners.push((
            "uncoded".into(),
            Arc::new(|_| assigner(builtin::UncodedUniformAssigner)),
        ));
        r.assigners.push((
            "coded".into(),
            Arc::new(|_| assigner(builtin::CodedUniformAssigner)),
        ));
        r.assigners.push((
            "dedi-simple".into(),
            Arc::new(|values| assigner(builtin::DediSimpleAssigner { values })),
        ));
        r.assigners.push((
            "dedi-iter".into(),
            Arc::new(|values| assigner(builtin::DediIterAssigner { values })),
        ));
        r.assigners.push((
            "frac".into(),
            Arc::new(|values| assigner(builtin::FracAssigner { values })),
        ));
        r.assigners.push((
            "optimal".into(),
            Arc::new(|_| assigner(builtin::FracOptimalAssigner)),
        ));
        r.allocators.push((
            "markov".into(),
            Arc::new(|| allocator(builtin::MarkovAllocator)),
        ));
        r.allocators.push((
            "exact".into(),
            Arc::new(|| allocator(builtin::ExactAllocator)),
        ));
        r.allocators
            .push(("sca".into(), Arc::new(|| allocator(builtin::ScaAllocator))));
        r.allocators.push((
            "uncoded-split".into(),
            Arc::new(|| allocator(builtin::UncodedSplitAllocator)),
        ));
        r
    }
}

static REGISTRY: OnceLock<Mutex<Registry>> = OnceLock::new();

fn with_registry<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    let lock = REGISTRY.get_or_init(|| Mutex::new(Registry::builtins()));
    let mut guard = lock.lock().unwrap_or_else(|poison| poison.into_inner());
    f(&mut guard)
}

/// Register (or shadow) an assigner under `name`.
pub fn register_assigner<F>(name: &str, factory: F)
where
    F: Fn(ValueModel) -> Arc<dyn Assigner> + Send + Sync + 'static,
{
    with_registry(|r| r.assigners.push((name.to_string(), Arc::new(factory))));
}

/// Register (or shadow) a load allocator under `name`.
pub fn register_allocator<F>(name: &str, factory: F)
where
    F: Fn() -> Arc<dyn LoadAllocator> + Send + Sync + 'static,
{
    with_registry(|r| r.allocators.push((name.to_string(), Arc::new(factory))));
}

/// Allocators that exist only as benchmark pins (see
/// [`crate::policy::Assigner::pinned_allocator`]); they are registered so
/// pinning resolves, but are not user-selectable: the uncoded split's
/// no-redundancy loads and slowest-mean `t_est` are only meaningful under
/// uncoded completion semantics.
const INTERNAL_ALLOCATORS: &[&str] = &["uncoded-split"];

/// Resolve `(policy, values, loads)` into a strategy pair. The assigner
/// may pin its allocator (benchmarks do); otherwise `loads` is honored.
pub fn resolve(
    policy: &str,
    values: ValueModel,
    loads: &str,
) -> anyhow::Result<ResolvedPolicy> {
    let (assigner_factory, allocator_for) = with_registry(|r| {
        let af = r
            .assigners
            .iter()
            .rev()
            .find(|(n, _)| n == policy)
            .map(|(_, f)| Arc::clone(f));
        // Clone the allocator table so the lock is released before any
        // factory code runs.
        let al: Vec<(String, AllocatorFactory)> = r
            .allocators
            .iter()
            .map(|(n, f)| (n.clone(), Arc::clone(f)))
            .collect();
        (af, al)
    });
    let assigner_factory = assigner_factory.ok_or_else(|| {
        anyhow::anyhow!(
            "unknown policy '{policy}' (known: {})",
            assigner_names().join(", ")
        )
    })?;
    let assigner = (assigner_factory.as_ref())(values);
    let loads_key = match assigner.pinned_allocator() {
        Some(pinned) => pinned,
        None => {
            anyhow::ensure!(
                !INTERNAL_ALLOCATORS.contains(&loads),
                "load method '{loads}' is internal (used only as a benchmark pin)"
            );
            loads
        }
    };
    let allocator = allocator_for
        .iter()
        .rev()
        .find(|(n, _)| n == loads_key)
        .map(|(_, f)| (f.as_ref())())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown load method '{loads_key}' (known: {})",
                public_allocator_names().join(", ")
            )
        })?;
    Ok(ResolvedPolicy {
        policy: policy.to_string(),
        loads: loads_key.to_string(),
        assigner,
        allocator,
    })
}

/// All registered assigner names (deduplicated, first-registration order).
pub fn assigner_names() -> Vec<String> {
    with_registry(|r| dedup(r.assigners.iter().map(|(n, _)| n.clone())))
}

/// All registered allocator names (deduplicated, first-registration
/// order), including pin-only internals.
pub fn allocator_names() -> Vec<String> {
    with_registry(|r| dedup(r.allocators.iter().map(|(n, _)| n.clone())))
}

/// User-selectable allocator names: [`allocator_names`] minus the
/// pin-only internals. This is what `--loads` accepts and what help
/// listings should show.
pub fn public_allocator_names() -> Vec<String> {
    allocator_names()
        .into_iter()
        .filter(|n| !INTERNAL_ALLOCATORS.contains(&n.as_str()))
        .collect()
}

fn dedup(names: impl Iterator<Item = String>) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for n in names {
        if !out.contains(&n) {
            out.push(n);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CommModel, Scenario};

    #[test]
    fn builtins_are_registered() {
        let a = assigner_names();
        for name in ["uncoded", "coded", "dedi-simple", "dedi-iter", "frac", "optimal"] {
            assert!(a.iter().any(|n| n == name), "missing assigner {name}");
        }
        let l = allocator_names();
        for name in ["markov", "exact", "sca", "uncoded-split"] {
            assert!(l.iter().any(|n| n == name), "missing allocator {name}");
        }
    }

    #[test]
    fn internal_allocators_are_pin_only() {
        // Pinning resolves the internal allocator…
        let r = resolve("uncoded", ValueModel::Markov, "markov").unwrap();
        assert_eq!(r.loads, "uncoded-split");
        // …but selecting it directly is rejected, and it is hidden from
        // the user-facing listing while remaining registered.
        let e = resolve("dedi-iter", ValueModel::Markov, "uncoded-split").unwrap_err();
        assert!(e.to_string().contains("internal"), "{e}");
        assert!(!public_allocator_names().iter().any(|n| n == "uncoded-split"));
        assert!(allocator_names().iter().any(|n| n == "uncoded-split"));
    }

    #[test]
    fn unknown_names_error_with_suggestions() {
        let e = resolve("bogus", ValueModel::Markov, "markov").unwrap_err();
        assert!(e.to_string().contains("dedi-iter"), "{e}");
        let e = resolve("dedi-iter", ValueModel::Markov, "bogus").unwrap_err();
        assert!(e.to_string().contains("markov"), "{e}");
    }

    #[test]
    fn shadowing_overrides_builtin() {
        // Register a shadow of "markov" under a throwaway name, then
        // shadow THAT name again — the later registration must win.
        use crate::alloc::Allocation;
        use crate::policy::LoadAllocator;
        struct Marked(f64);
        impl LoadAllocator for Marked {
            fn allocate(
                &self,
                _s: &Scenario,
                _m: usize,
                nodes: &[usize],
                _shares: &[(f64, f64)],
            ) -> Allocation {
                Allocation {
                    loads: vec![self.0; nodes.len()],
                    t_star: self.0,
                }
            }
        }
        register_allocator("shadow-test", || Arc::new(Marked(1.0)) as Arc<dyn LoadAllocator>);
        register_allocator("shadow-test", || Arc::new(Marked(2.0)) as Arc<dyn LoadAllocator>);
        let r = resolve("dedi-iter", ValueModel::Markov, "shadow-test").unwrap();
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        let p = r.build(&s);
        assert!((p.masters[0].t_est - 2.0).abs() < 1e-12);
    }
}
