//! Scenario configuration: the §V simulation settings as first-class
//! configs, plus a JSON config-file system for custom deployments.
//!
//! A [`Scenario`] is the static description of a deployment: `M` masters
//! (each with a task size `L_m` and local-processing parameters) and `N`
//! shared workers with per-(m, n) link parameters `(γ, a, u)`.
//!
//! Builders reproduce the paper's settings exactly:
//! * [`Scenario::small_scale`] — M=2, N=5, `a_{m,n} ∈ {0.2, 0.25, 0.3}` ms,
//!   `a_{m,0} ∈ {0.4, 0.5}` ms, `u = 1/a`, `L = 10⁴` (§V-A);
//! * [`Scenario::large_scale`] — M=4, N=50, `a_{m,n} ∈ [0.05, 0.5]` ms;
//! * [`Scenario::ec2`] — Fig. 8: 4 t2.micro masters, 40 t2.micro + 10
//!   c5.large workers with the paper's fitted shifted-exponentials.

use crate::model::dist::{DelayFamily, FamilyKind, LinkDelay, TraceDist};
use crate::model::params::{theta_fractional, theta_from_comp_mean, LinkParams};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Communication-delay regime of a scenario.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommModel {
    /// Communication delay modeled per eq. (1) with per-link γ.
    Stochastic,
    /// Computation-dominant (§III-B, Figs. 2, 3, 8): the comm leg is
    /// ignored entirely.
    CompDominant,
}

/// One master's static description.
#[derive(Clone, Debug)]
pub struct MasterCfg {
    /// Task size `L_m`: rows of `A_m` that must be recovered.
    pub l_rows: f64,
    /// Local-processing parameters `(a_{m,0}, u_{m,0})`.
    pub local: LinkParams,
}

/// A full deployment description.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub comm: CommModel,
    pub masters: Vec<MasterCfg>,
    /// `links[m][n-1]` = parameters of link (master m, worker n), n ∈ 1..=N.
    pub links: Vec<Vec<LinkParams>>,
    /// Delay-trace table for [`FamilyKind::Trace`] links (usually empty;
    /// register with [`Scenario::add_trace`] or a `"traces"` JSON array).
    pub traces: Vec<TraceDist>,
}

impl Scenario {
    /// Number of masters `M`.
    pub fn n_masters(&self) -> usize {
        self.masters.len()
    }

    /// Number of shared workers `N`.
    pub fn n_workers(&self) -> usize {
        self.links.first().map_or(0, |row| row.len())
    }

    /// Link parameters of (master `m`, node `n`); `n = 0` is local.
    pub fn link(&self, m: usize, n: usize) -> LinkParams {
        if n == 0 {
            self.masters[m].local
        } else {
            let p = self.links[m][n - 1];
            match self.comm {
                CommModel::Stochastic => p,
                // Computation-dominant: drop the comm leg (γ → ∞).
                CommModel::CompDominant => LinkParams {
                    gamma: f64::INFINITY,
                    ..p
                },
            }
        }
    }

    pub fn l_rows(&self, m: usize) -> f64 {
        self.masters[m].l_rows
    }

    /// Resolve the per-row computation-delay family of link (m, n)
    /// against this scenario's trace table.
    pub fn comp_family(&self, m: usize, n: usize) -> DelayFamily {
        let p = self.link(m, n);
        p.family.resolve(p.a, p.u, &self.traces)
    }

    /// Family-aware compile of one sub-task's total delay — the single
    /// entry point the Monte-Carlo kernels and the coordinator use.
    /// Shifted-exponential links go through [`LinkDelay::new`] (the
    /// exact legacy arithmetic, bit-for-bit); every other family is
    /// resolved and block-scaled.
    pub fn link_delay(&self, m: usize, n: usize, l: f64, k: f64, b: f64) -> LinkDelay {
        let p = self.link(m, n);
        match p.family {
            FamilyKind::ShiftedExp => LinkDelay::new(&p, l, k, b),
            kind => LinkDelay::with_family(&p, &kind.resolve(p.a, p.u, &self.traces), l, k, b),
        }
    }

    /// Family-aware expected unit delay θ (eqs. 10/24 via Remark 1):
    /// comm mean + `E[X]/k` with `X` the link's per-row computation
    /// family. Shifted-exponential links evaluate the legacy
    /// [`theta_fractional`] formula bit-for-bit; other families thread
    /// their true first moment ([`DelayFamily::mean`]) to the planner —
    /// this is the moment interface the Markov-inequality allocators
    /// consume instead of raw `(a, u)` pairs.
    pub fn theta(&self, m: usize, n: usize, k: f64, b: f64) -> f64 {
        let p = self.link(m, n);
        match p.family {
            FamilyKind::ShiftedExp => theta_fractional(&p, k, b),
            kind => theta_from_comp_mean(
                &p,
                kind.resolve(p.a, p.u, &self.traces).mean(),
                k,
                b,
            ),
        }
    }

    /// Register a delay trace; returns the id [`FamilyKind::Trace`]
    /// links reference.
    pub fn add_trace(&mut self, trace: TraceDist) -> usize {
        self.traces.push(trace);
        self.traces.len() - 1
    }

    /// The scenario restricted to the 1-based worker ids in `active`
    /// (strictly increasing, in range): worker `j` of the result is
    /// worker `active[j-1]` of `self`; masters, local links, comm model
    /// and the trace table are untouched. The serving layer plans on
    /// this subset while workers are away (churn) and remaps the plan's
    /// node ids back onto the full fleet.
    pub fn subset_workers(&self, active: &[usize]) -> anyhow::Result<Scenario> {
        let n = self.n_workers();
        anyhow::ensure!(!active.is_empty(), "subset_workers needs ≥ 1 active worker");
        for (i, &w) in active.iter().enumerate() {
            anyhow::ensure!(
                (1..=n).contains(&w),
                "subset_workers: worker id {w} outside 1..={n}"
            );
            anyhow::ensure!(
                i == 0 || active[i - 1] < w,
                "subset_workers: ids must be strictly increasing"
            );
        }
        Ok(Scenario {
            name: format!("{} [{}/{n} workers]", self.name, active.len()),
            comm: self.comm,
            masters: self.masters.clone(),
            links: self
                .links
                .iter()
                .map(|row| active.iter().map(|&w| row[w - 1]).collect())
                .collect(),
            traces: self.traces.clone(),
        }
        .check())
    }

    fn check(self) -> Self {
        assert!(!self.masters.is_empty(), "scenario needs ≥1 master");
        assert_eq!(
            self.links.len(),
            self.masters.len(),
            "links must have one row per master"
        );
        let n = self.n_workers();
        assert!(
            self.links.iter().all(|row| row.len() == n),
            "ragged link matrix"
        );
        for (m, row) in self.links.iter().enumerate() {
            for (w, p) in row.iter().enumerate() {
                p.family.validate(self.traces.len()).unwrap_or_else(|e| {
                    panic!("link (master {m}, worker {}): {e}", w + 1)
                });
            }
        }
        for (m, mc) in self.masters.iter().enumerate() {
            mc.local
                .family
                .validate(self.traces.len())
                .unwrap_or_else(|e| panic!("master {m} local link: {e}"));
        }
        self
    }

    // ------------------------------------------------------------------
    // Paper scenarios
    // ------------------------------------------------------------------

    /// §V small-scale: M=2, N=5. `gamma_ratio` is γ/u (2.0 in Fig. 4;
    /// swept in Fig. 6; irrelevant when `comm` is `CompDominant`).
    pub fn small_scale(seed: u64, gamma_ratio: f64, comm: CommModel) -> Self {
        Self::random(
            "small-scale (M=2, N=5)",
            2,
            5,
            1e4,
            AShift::Choice(&[0.2, 0.25, 0.3]),
            gamma_ratio,
            comm,
            seed,
        )
    }

    /// §V large-scale: M=4, N=50.
    pub fn large_scale(seed: u64, gamma_ratio: f64, comm: CommModel) -> Self {
        Self::random(
            "large-scale (M=4, N=50)",
            4,
            50,
            1e4,
            AShift::Range(0.05, 0.5),
            gamma_ratio,
            comm,
            seed,
        )
    }

    /// Fully parameterized random scenario following the paper's recipe:
    /// worker shifts from `a_dist`, master shifts from {0.4, 0.5} ms,
    /// `u = 1/a`, `γ = gamma_ratio·u`.
    pub fn random(
        name: &str,
        m: usize,
        n: usize,
        l_rows: f64,
        a_dist: AShift,
        gamma_ratio: f64,
        comm: CommModel,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let masters = (0..m)
            .map(|_| {
                let a0 = *rng.choose(&[0.4, 0.5]);
                MasterCfg {
                    l_rows,
                    local: LinkParams::local(a0, 1.0 / a0),
                }
            })
            .collect();
        let links = (0..m)
            .map(|_| {
                (0..n)
                    .map(|_| {
                        let a = a_dist.sample(&mut rng);
                        let u = 1.0 / a;
                        LinkParams::new(gamma_ratio * u, a, u)
                    })
                    .collect()
            })
            .collect();
        Scenario {
            name: name.to_string(),
            comm,
            masters,
            links,
            traces: Vec::new(),
        }
        .check()
    }

    /// Fig. 8: EC2-fitted computation-dominant scenario. 4 masters
    /// (t2.micro local), `n_t2` t2.micro + `n_c5` c5.large workers.
    /// Parameters are per coded row (ms): t2.micro a=1.36, u=4.976;
    /// c5.large a=0.97, u=19.29 (paper §V-C).
    ///
    /// `stragglers` enables the heavy-tail mixture that stands in for the
    /// paper's *measured* traces (t2.micro is burstable: CPU-credit
    /// throttling produces multi-× slowdowns that the fitted shifted
    /// exponential cannot reproduce — DESIGN.md §Substitutions). The
    /// planner always plans with the fitted parameters, like the paper.
    pub fn ec2(n_t2: usize, n_c5: usize, stragglers: bool) -> Self {
        use crate::traces::ec2::{C5_LARGE, T2_MICRO, T2_MICRO_THROTTLE};
        let m = 4;
        let t2_link = || {
            // γ is irrelevant under CompDominant; keep a finite
            // placeholder so the config serializes cleanly.
            let p = LinkParams::new(1e9, T2_MICRO.a, T2_MICRO.u);
            if stragglers {
                p.with_straggler(T2_MICRO_THROTTLE.0, T2_MICRO_THROTTLE.1)
            } else {
                p
            }
        };
        let masters = (0..m)
            .map(|_| MasterCfg {
                l_rows: 1e4,
                local: LinkParams::local(T2_MICRO.a, T2_MICRO.u),
            })
            .collect();
        let links = (0..m)
            .map(|_| {
                (0..n_t2 + n_c5)
                    .map(|i| {
                        if i < n_t2 {
                            t2_link()
                        } else {
                            LinkParams::new(1e9, C5_LARGE.a, C5_LARGE.u)
                        }
                    })
                    .collect()
            })
            .collect();
        Scenario {
            name: format!("ec2 (4 masters, {n_t2} t2.micro + {n_c5} c5.large)"),
            comm: CommModel::CompDominant,
            masters,
            links,
            traces: Vec::new(),
        }
        .check()
    }

    // ------------------------------------------------------------------
    // JSON config system
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()));
        j.set(
            "comm",
            Json::Str(
                match self.comm {
                    CommModel::Stochastic => "stochastic",
                    CommModel::CompDominant => "comp_dominant",
                }
                .into(),
            ),
        );
        j.set(
            "masters",
            Json::Arr(
                self.masters
                    .iter()
                    .map(|mc| {
                        let mut o = Json::obj();
                        o.set("l_rows", Json::Num(mc.l_rows));
                        o.set("a0", Json::Num(mc.local.a));
                        o.set("u0", Json::Num(mc.local.u));
                        if mc.local.family != FamilyKind::ShiftedExp {
                            o.set("family", mc.local.family.to_json());
                        }
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "links",
            Json::Arr(
                self.links
                    .iter()
                    .map(|row| {
                        Json::Arr(
                            row.iter()
                                .map(|p| {
                                    let mut o = Json::obj();
                                    o.set("gamma", Json::Num(p.gamma));
                                    o.set("a", Json::Num(p.a));
                                    o.set("u", Json::Num(p.u));
                                    if p.family != FamilyKind::ShiftedExp {
                                        o.set("family", p.family.to_json());
                                    }
                                    o
                                })
                                .collect(),
                        )
                    })
                    .collect(),
            ),
        );
        if !self.traces.is_empty() {
            j.set(
                "traces",
                Json::Arr(self.traces.iter().map(TraceDist::to_json).collect()),
            );
        }
        j
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let get = |j: &Json, k: &str| -> anyhow::Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("missing/invalid field '{k}'"))
        };
        let name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("custom")
            .to_string();
        let comm = match j.get("comm").and_then(Json::as_str) {
            Some("comp_dominant") => CommModel::CompDominant,
            _ => CommModel::Stochastic,
        };
        let traces = match j.get("traces") {
            None | Some(Json::Null) => Vec::new(),
            Some(tj) => tj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("'traces' must be an array"))?
                .iter()
                .map(TraceDist::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?,
        };
        let masters = j
            .get("masters")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing 'masters'"))?
            .iter()
            .map(|mj| {
                let mut local = LinkParams::local(get(mj, "a0")?, get(mj, "u0")?);
                if let Some(fj) = mj.get("family") {
                    let kind = FamilyKind::from_json(fj)?;
                    kind.validate(traces.len())?;
                    local.family = kind;
                }
                Ok(MasterCfg {
                    l_rows: get(mj, "l_rows")?,
                    local,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let links = j
            .get("links")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing 'links'"))?
            .iter()
            .map(|row| {
                row.as_arr()
                    .ok_or_else(|| anyhow::anyhow!("'links' rows must be arrays"))?
                    .iter()
                    .map(|pj| {
                        let mut p = LinkParams::new(
                            get(pj, "gamma")?,
                            get(pj, "a")?,
                            get(pj, "u")?,
                        );
                        if let Some(fj) = pj.get("family") {
                            let kind = FamilyKind::from_json(fj)?;
                            kind.validate(traces.len())?;
                            p.family = kind;
                        }
                        Ok(p)
                    })
                    .collect::<anyhow::Result<Vec<_>>>()
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Scenario {
            name,
            comm,
            masters,
            links,
            traces,
        }
        .check())
    }

    pub fn from_file(path: &str) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }
}

/// Composable scenario rewrites — the building blocks of the experiment
/// layer's sweep axes ([`crate::experiment::SweepSpec`]): start from a
/// base scenario and apply transforms to obtain each swept variant, so a
/// grid over (γ, u, L, straggler mix) never needs a bespoke builder.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Transform {
    /// Set every worker link's communication rate to `ratio · u` (the
    /// γ/u sweep of Fig. 6). Equivalent to constructing the scenario with
    /// this `gamma_ratio` — the computation draws are untouched.
    GammaRatio(f64),
    /// Scale every worker link's computation rate `u` (faster / slower
    /// worker fleets; shift `a` and comm rate stay put).
    ScaleU(f64),
    /// Set every master's task size `L_m`.
    LRows(f64),
    /// Attach a heavy-tail straggler mixture to every worker link
    /// (sampling only — the planner keeps seeing the base parameters,
    /// like the paper). `prob = 0` is a no-op.
    Straggler { prob: f64, slowdown: f64 },
    /// Switch the communication regime.
    Comm(CommModel),
    /// Select the computation-delay family of every worker link
    /// (master-local links keep the shifted exponential). Parametric
    /// kinds are mean-matched to each link's fitted `(a, u)`
    /// ([`FamilyKind::resolve`]); trace ids must already be registered
    /// on the scenario ([`Scenario::add_trace`]).
    Family(FamilyKind),
}

impl Transform {
    /// Apply this transform in place.
    pub fn apply(&self, s: &mut Scenario) {
        match *self {
            Transform::GammaRatio(r) => {
                assert!(r > 0.0, "gamma ratio must be positive, got {r}");
                for row in &mut s.links {
                    for p in row.iter_mut() {
                        p.gamma = r * p.u;
                    }
                }
            }
            Transform::ScaleU(f) => {
                assert!(f > 0.0, "u scale must be positive, got {f}");
                for row in &mut s.links {
                    for p in row.iter_mut() {
                        p.u *= f;
                    }
                }
            }
            Transform::LRows(l) => {
                assert!(l > 0.0, "L must be positive, got {l}");
                for mc in &mut s.masters {
                    mc.l_rows = l;
                }
            }
            Transform::Straggler { prob, slowdown } => {
                if prob > 0.0 {
                    for row in &mut s.links {
                        for p in row.iter_mut() {
                            *p = p.with_straggler(prob, slowdown);
                        }
                    }
                }
            }
            Transform::Comm(c) => s.comm = c,
            Transform::Family(kind) => {
                kind.validate(s.traces.len())
                    .expect("invalid delay-family transform");
                for row in &mut s.links {
                    for p in row.iter_mut() {
                        p.family = kind;
                    }
                }
            }
        }
    }
}

impl Scenario {
    /// Apply a sequence of [`Transform`]s in order and return the result.
    pub fn transformed(mut self, transforms: &[Transform]) -> Self {
        for t in transforms {
            t.apply(&mut self);
        }
        self
    }
}

/// Distribution of worker computation shifts in randomized scenarios.
#[derive(Clone, Copy, Debug)]
pub enum AShift {
    /// Uniform choice from a finite set (small-scale: {0.2, 0.25, 0.3}).
    Choice(&'static [f64]),
    /// Uniform over a range (large-scale: [0.05, 0.5]).
    Range(f64, f64),
}

impl AShift {
    fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            AShift::Choice(xs) => *rng.choose(xs),
            AShift::Range(lo, hi) => rng.range(*lo, *hi),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scale_matches_paper_recipe() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        assert_eq!(s.n_masters(), 2);
        assert_eq!(s.n_workers(), 5);
        for m in 0..2 {
            assert_eq!(s.l_rows(m), 1e4);
            let a0 = s.link(m, 0).a;
            assert!(a0 == 0.4 || a0 == 0.5);
            assert!((s.link(m, 0).u - 1.0 / a0).abs() < 1e-12);
            for n in 1..=5 {
                let p = s.link(m, n);
                assert!([0.2, 0.25, 0.3].contains(&p.a));
                assert!((p.u - 1.0 / p.a).abs() < 1e-12);
                assert!((p.gamma - 2.0 * p.u).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn large_scale_shapes() {
        let s = Scenario::large_scale(7, 2.0, CommModel::Stochastic);
        assert_eq!(s.n_masters(), 4);
        assert_eq!(s.n_workers(), 50);
        for m in 0..4 {
            for n in 1..=50 {
                let p = s.link(m, n);
                assert!((0.05..=0.5).contains(&p.a));
            }
        }
    }

    #[test]
    fn comp_dominant_drops_comm_leg() {
        let s = Scenario::small_scale(1, 2.0, CommModel::CompDominant);
        for n in 1..=5 {
            assert!(s.link(0, n).is_local(), "γ must be ∞ in comp-dominant");
        }
    }

    #[test]
    fn ec2_scenario_profiles() {
        let s = Scenario::ec2(40, 10, false);
        assert_eq!(s.n_masters(), 4);
        assert_eq!(s.n_workers(), 50);
        assert!((s.link(0, 1).a - 1.36).abs() < 1e-9); // t2.micro
        assert!((s.link(0, 50).a - 0.97).abs() < 1e-9); // c5.large
        assert!((s.link(0, 50).u - 19.29).abs() < 1e-9);
        assert_eq!(s.comm, CommModel::CompDominant);
    }

    #[test]
    fn seeded_scenarios_are_deterministic() {
        let a = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let b = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        for m in 0..2 {
            for n in 0..=5 {
                assert_eq!(a.link(m, n), b.link(m, n));
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let j = s.to_json();
        let back = Scenario::from_json(&j).unwrap();
        assert_eq!(back.n_masters(), s.n_masters());
        assert_eq!(back.n_workers(), s.n_workers());
        for m in 0..s.n_masters() {
            assert_eq!(back.l_rows(m), s.l_rows(m));
            for n in 0..=s.n_workers() {
                let (a, b) = (s.link(m, n), back.link(m, n));
                assert!((a.a - b.a).abs() < 1e-12);
                assert!((a.u - b.u).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn subset_workers_selects_columns() {
        let s = Scenario::small_scale(11, 2.0, CommModel::Stochastic);
        let sub = s.subset_workers(&[2, 5]).unwrap();
        assert_eq!(sub.n_workers(), 2);
        assert_eq!(sub.n_masters(), s.n_masters());
        for m in 0..s.n_masters() {
            assert_eq!(sub.link(m, 0), s.link(m, 0), "local link untouched");
            assert_eq!(sub.link(m, 1), s.link(m, 2));
            assert_eq!(sub.link(m, 2), s.link(m, 5));
        }
        // Full subset reproduces the original link matrix.
        let all = s.subset_workers(&[1, 2, 3, 4, 5]).unwrap();
        for m in 0..s.n_masters() {
            for w in 1..=5 {
                assert_eq!(all.link(m, w), s.link(m, w));
            }
        }
        // Malformed subsets are graceful errors.
        assert!(s.subset_workers(&[]).is_err());
        assert!(s.subset_workers(&[0]).is_err());
        assert!(s.subset_workers(&[6]).is_err());
        assert!(s.subset_workers(&[3, 3]).is_err());
        assert!(s.subset_workers(&[4, 2]).is_err());
    }

    #[test]
    fn gamma_ratio_transform_equals_direct_construction() {
        // The Fig. 6 parity requirement: transforming the base scenario
        // must be indistinguishable from constructing with that ratio.
        for ratio in [0.5, 4.0] {
            let direct = Scenario::large_scale(7, ratio, CommModel::Stochastic);
            let transformed = Scenario::large_scale(7, 2.0, CommModel::Stochastic)
                .transformed(&[Transform::GammaRatio(ratio)]);
            for m in 0..direct.n_masters() {
                for n in 0..=direct.n_workers() {
                    assert_eq!(direct.link(m, n), transformed.link(m, n), "m={m} n={n}");
                }
            }
        }
    }

    #[test]
    fn transforms_compose_in_order() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic).transformed(&[
            Transform::ScaleU(2.0),
            Transform::LRows(500.0),
            Transform::Straggler {
                prob: 0.1,
                slowdown: 5.0,
            },
            Transform::Comm(CommModel::CompDominant),
        ]);
        let base = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
        assert_eq!(s.comm, CommModel::CompDominant);
        for m in 0..s.n_masters() {
            assert_eq!(s.l_rows(m), 500.0);
            // master-local links untouched by worker transforms
            assert_eq!(s.masters[m].local, base.masters[m].local);
            for n in 1..=s.n_workers() {
                let (p, b) = (s.links[m][n - 1], base.links[m][n - 1]);
                assert!((p.u - 2.0 * b.u).abs() < 1e-12);
                assert_eq!(p.a, b.a);
                assert_eq!(p.gamma, b.gamma);
                assert!(p.straggler.is_some());
            }
        }
        // zero-probability straggler is a no-op
        let s2 = Scenario::small_scale(1, 2.0, CommModel::Stochastic).transformed(&[
            Transform::Straggler {
                prob: 0.0,
                slowdown: 5.0,
            },
        ]);
        assert!(s2.links[0][0].straggler.is_none());
    }

    #[test]
    fn family_transform_hits_worker_links_only() {
        let s = Scenario::small_scale(1, 2.0, CommModel::Stochastic)
            .transformed(&[Transform::Family(FamilyKind::Weibull { shape: 0.6 })]);
        for m in 0..s.n_masters() {
            assert_eq!(s.link(m, 0).family, FamilyKind::ShiftedExp, "local link");
            for n in 1..=s.n_workers() {
                assert_eq!(s.link(m, n).family, FamilyKind::Weibull { shape: 0.6 });
                // (a, u) untouched: the family is mean-matched on top.
                let base = Scenario::small_scale(1, 2.0, CommModel::Stochastic);
                assert_eq!(s.link(m, n).a, base.link(m, n).a);
                assert_eq!(s.link(m, n).u, base.link(m, n).u);
            }
        }
        // CompDominant still drops the comm leg, family intact.
        let cd = Scenario::small_scale(1, 2.0, CommModel::CompDominant)
            .transformed(&[Transform::Family(FamilyKind::Pareto { alpha: 2.5 })]);
        assert!(cd.link(0, 1).is_local());
        assert_eq!(cd.link(0, 1).family, FamilyKind::Pareto { alpha: 2.5 });
    }

    #[test]
    fn family_json_roundtrip_with_traces() {
        let mut s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
        let id = s.add_trace(TraceDist::from_samples("toy", vec![0.5, 1.0, 2.0]).unwrap());
        s = s.transformed(&[Transform::Family(FamilyKind::Trace { id })]);
        let text = s.to_json().to_string_pretty();
        let back = Scenario::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.traces.len(), 1);
        assert_eq!(back.traces[0].name(), "toy");
        for n in 1..=back.n_workers() {
            assert_eq!(back.link(0, n).family, FamilyKind::Trace { id: 0 });
        }
        // Trace id out of range is a graceful JSON error, not a panic.
        let bad = text.replace("\"id\": 0", "\"id\": 7");
        assert!(Scenario::from_json(&crate::util::json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn master_local_family_roundtrips_too() {
        // A programmatically-set local family must survive export →
        // reload, like worker links do.
        let mut s = Scenario::small_scale(4, 2.0, CommModel::Stochastic);
        s.masters[0].local = s.masters[0]
            .local
            .with_family(FamilyKind::Weibull { shape: 0.7 });
        let text = s.to_json().to_string_pretty();
        let back = Scenario::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.link(0, 0).family, FamilyKind::Weibull { shape: 0.7 });
        assert_eq!(back.link(1, 0).family, FamilyKind::ShiftedExp);
    }

    #[test]
    fn family_aware_theta_dispatch() {
        // Shifted-exp links: bit-for-bit the legacy formula.
        let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        for n in 0..=s.n_workers() {
            assert_eq!(s.theta(0, n, 1.0, 1.0), theta_fractional(&s.link(0, n), 1.0, 1.0));
            assert_eq!(s.theta(0, n, 0.5, 0.25), theta_fractional(&s.link(0, n), 0.5, 0.25));
        }
        // Mean-matched parametric families: same θ up to rounding.
        for kind in [
            FamilyKind::Weibull { shape: 0.6 },
            FamilyKind::Pareto { alpha: 2.5 },
            FamilyKind::Bimodal { prob: 0.05, slow: 10.0 },
        ] {
            let t = Scenario::small_scale(5, 2.0, CommModel::Stochastic)
                .transformed(&[Transform::Family(kind)]);
            for n in 1..=t.n_workers() {
                let want = theta_fractional(&t.link(0, n), 0.5, 0.5);
                let got = t.theta(0, n, 0.5, 0.5);
                assert!(
                    (got - want).abs() / want < 1e-9,
                    "{kind:?} n={n}: {got} vs {want}"
                );
            }
        }
        // Trace-driven links: θ uses the TRUE trace mean, not (a, u).
        let mut t = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
        let id = t.add_trace(TraceDist::from_samples("slow", vec![5.0, 7.0]).unwrap());
        let t = t.transformed(&[Transform::Family(FamilyKind::Trace { id })]);
        let p = t.link(0, 1);
        let got = t.theta(0, 1, 1.0, 1.0);
        let want = 1.0 / p.gamma + 6.0; // comm mean + trace mean
        assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        // Zero shares still degrade to ∞ like theta_fractional.
        assert!(t.theta(0, 1, 0.0, 1.0).is_infinite());
    }

    #[test]
    fn link_delay_dispatches_on_family() {
        let s = Scenario::small_scale(6, 2.0, CommModel::Stochastic);
        let d = s.link_delay(0, 1, 10.0, 1.0, 1.0);
        assert!(matches!(d.comp(), DelayFamily::ShiftedExp { .. }));
        let t = Scenario::small_scale(6, 2.0, CommModel::Stochastic)
            .transformed(&[Transform::Family(FamilyKind::Weibull { shape: 0.7 })]);
        let d = t.link_delay(0, 1, 10.0, 1.0, 1.0);
        assert!(matches!(d.comp(), DelayFamily::Weibull { .. }));
        // Block scaling: mean equals comm mean + (l/k)·E[X] = l·θ.
        let want = 10.0 * t.theta(0, 1, 1.0, 1.0);
        assert!((d.mean() - want).abs() / want < 1e-9);
    }

    #[test]
    fn from_json_rejects_garbage() {
        assert!(Scenario::from_json(&Json::obj()).is_err());
        let j = crate::util::json::parse(r#"{"masters": [], "links": []}"#).unwrap();
        // empty masters must be rejected by check()
        assert!(std::panic::catch_unwind(|| Scenario::from_json(&j)).is_err()
            || Scenario::from_json(&j).is_err());
    }
}
