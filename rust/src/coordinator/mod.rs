//! The L3 coordinator: a real multi-master / shared-worker runtime.
//!
//! This is the executable counterpart of the Monte-Carlo engine: the plan
//! produced by the paper's algorithms is *deployed* — matrices are MDS-
//! encoded (through the Pallas encode artifact), coded row-blocks are
//! dispatched to worker threads over delay-injected channels (eq. 1–2
//! sampling, scaled to wall-clock), every worker executes its
//! `Ã_{m,n}·x_m` through the PJRT mat-vec artifact, and each master
//! decodes as soon as ANY `L_m` coded products have arrived, broadcasting
//! cancellation for the rest. Recovered results are verified against the
//! direct product.
//!
//! Design notes:
//! * **virtual time** — the paper's delays are milliseconds of EC2
//!   compute/network; here they are sampled from the same distributions
//!   and mapped to wall-clock via `time_scale` (default 1:1 ms). Arrival
//!   order — which drives decode and cancellation — is therefore faithful
//!   to the model, while the actual linear algebra runs for real.
//! * **processor sharing** — a worker serving several masters holds one
//!   queue per sub-task and emits each at its own sampled deadline;
//!   fractional `k`/`b` shares are already reflected in the sampled
//!   delays (eq. 24).
//! * **threads, not tokio** — offline environment (DESIGN.md
//!   §Substitutions); one OS thread per worker + an mpsc results bus.

pub mod worker;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use crate::coding::MdsCode;
use crate::config::Scenario;
use crate::health::{FaultPlan, HealthConfig, HealthEvent, HealthEventKind};
use crate::plan::{self, MasterPlan, Plan, PlanSpec};
use crate::runtime::RuntimeHandle;
use crate::util::rng::Rng;
use worker::{Outcome, SubTask, TaskEvent, WorkerResult};

// The transport seam lives in `net`; re-exported here because it is
// selected on [`RunOptions`]/[`StreamOptions`].
pub use crate::net::transport::{TcpOptions, Transport};

/// Compute backend for encode + worker mat-vec.
#[derive(Clone)]
pub enum Backend {
    /// Through the AOT artifacts on the PJRT service (production path).
    Pjrt(RuntimeHandle),
    /// Native f32 loops (tests / environments without artifacts).
    Native,
    /// Fault injection: native compute, but a deterministic subset of
    /// sub-tasks fails — those whose `(master, coded_start)` hash lands
    /// in the failing residue class (independent of thread scheduling,
    /// so tests are reproducible). A failed sub-task behaves like a
    /// straggler that never returns — the MDS redundancy must absorb it
    /// (chaos-tested in `failed_computations_absorbed_by_code`).
    Flaky { every: usize },
}

impl Backend {
    /// Deterministic fault-injecting backend failing ~1/`every` of the
    /// sub-tasks.
    pub fn flaky(every: usize) -> Self {
        assert!(every >= 2, "every=1 would fail all computations");
        Backend::Flaky { every }
    }
}

/// Coordinator configuration (plan built internally from `spec`; use
/// [`run_plan`] + [`RunOptions`] to deploy an existing [`Plan`]).
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub scenario: Scenario,
    pub spec: PlanSpec,
    /// Task width `S_m` (columns of every `A_m`).
    pub cols: usize,
    /// Wall-clock seconds per virtual millisecond (1e-3 = real-time ms).
    pub time_scale: f64,
    pub backend: Backend,
    pub seed: u64,
    /// Verify recovered `A_m x_m` against the direct product.
    pub verify: bool,
}

/// Execution options for [`run_plan`] — everything the coordinator needs
/// beyond (scenario, plan).
#[derive(Clone)]
pub struct RunOptions {
    /// Task width `S_m` (columns of every `A_m`).
    pub cols: usize,
    /// Wall-clock seconds per virtual millisecond (1e-3 = real-time ms).
    pub time_scale: f64,
    pub backend: Backend,
    pub seed: u64,
    /// Verify recovered `A_m x_m` against the direct product.
    pub verify: bool,
    /// How sub-tasks reach workers: in-process threads (default) or TCP.
    pub transport: Transport,
    /// Injected faults (crash / gray / spike / slow-start / flaky), or
    /// `None` for a clean run. Applies to both transports.
    pub fault: Option<FaultPlan>,
    /// Heartbeat / breaker thresholds. Health tracking arms itself when
    /// `health.active(fault.is_some())` — a clean run with the default
    /// config keeps the PR-6 dispatch path bit-identical.
    pub health: HealthConfig,
}

/// Per-master outcome.
#[derive(Clone, Debug)]
pub struct MasterReport {
    /// Virtual completion delay (ms) — the paper's metric.
    pub completion_ms: f64,
    /// Planner's prediction `t_m*`.
    pub t_est_ms: f64,
    /// Coded rows received before decode fired.
    pub rows_used: usize,
    /// Coded rows whose sub-tasks were cancelled.
    pub rows_cancelled: usize,
    /// Max relative error |recovered − direct|/(1 + |direct|) over the
    /// task (if verified). Relative, because the LU decode of an L×L
    /// Gaussian sub-generator amplifies f32 rounding with L.
    pub max_rel_err: Option<f64>,
    /// Wall-clock spent in the encode call (ms).
    pub encode_wall_ms: f64,
}

/// Whole-run outcome.
#[derive(Clone, Debug)]
pub struct Report {
    pub label: String,
    pub masters: Vec<MasterReport>,
    pub wall_ms: f64,
    /// Sub-tasks computed / skipped-by-cancellation per worker thread.
    pub worker_computed: Vec<usize>,
    pub worker_skipped: Vec<usize>,
    /// Per-sub-task event log (observability; JSON via [`Report::to_json`]).
    pub events: Vec<TaskEvent>,
    /// Health timeline: suspicions, breaker transitions, disconnects and
    /// re-queues. Empty when health tracking is disarmed.
    pub health: Vec<HealthEvent>,
}

impl Report {
    /// System completion = slowest master (virtual ms).
    pub fn system_completion_ms(&self) -> f64 {
        self.masters
            .iter()
            .map(|m| m.completion_ms)
            .fold(0.0, f64::max)
    }

    pub fn all_verified(&self, tol: f64) -> bool {
        self.masters
            .iter()
            .all(|m| m.max_rel_err.map_or(false, |e| e <= tol))
    }

    /// Total backend compute wallclock (ms) across all workers.
    pub fn compute_wall_ms(&self) -> f64 {
        self.events.iter().map(|e| e.compute_wall_ms).sum()
    }

    /// Fraction of dispatched rows that were cancelled or failed —
    /// redundancy the cancellation mechanism saved.
    pub fn saved_fraction(&self) -> f64 {
        let total: usize = self.events.iter().map(|e| e.rows).sum();
        let saved: usize = self
            .events
            .iter()
            .filter(|e| e.outcome != Outcome::Computed)
            .map(|e| e.rows)
            .sum();
        if total == 0 {
            0.0
        } else {
            saved as f64 / total as f64
        }
    }

    /// Structured export for dashboards / regression diffing.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = Json::obj();
        j.set("label", Json::Str(self.label.clone()));
        j.set("verified", Json::Bool(self.all_verified(1e-2)));
        j.set("system_completion_ms", Json::Num(self.system_completion_ms()));
        j.set("wall_ms", Json::Num(self.wall_ms));
        j.set("compute_wall_ms", Json::Num(self.compute_wall_ms()));
        j.set("saved_fraction", Json::Num(self.saved_fraction()));
        j.set(
            "masters",
            Json::Arr(
                self.masters
                    .iter()
                    .map(|m| {
                        let mut o = Json::obj();
                        o.set("completion_ms", Json::Num(m.completion_ms));
                        o.set("t_est_ms", Json::Num(m.t_est_ms));
                        o.set("rows_used", Json::Num(m.rows_used as f64));
                        o.set("rows_cancelled", Json::Num(m.rows_cancelled as f64));
                        o.set(
                            "max_rel_err",
                            m.max_rel_err.map_or(Json::Null, Json::Num),
                        );
                        o.set("encode_wall_ms", Json::Num(m.encode_wall_ms));
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "events",
            Json::Arr(
                self.events
                    .iter()
                    .map(|e| {
                        let mut o = Json::obj();
                        o.set("worker", Json::Num(e.worker as f64));
                        o.set("master", Json::Num(e.master as f64));
                        o.set("rows", Json::Num(e.rows as f64));
                        o.set("deadline_ms", Json::Num(e.deadline_ms));
                        o.set("compute_wall_ms", Json::Num(e.compute_wall_ms));
                        o.set(
                            "outcome",
                            Json::Str(
                                match e.outcome {
                                    Outcome::Computed => "computed",
                                    Outcome::Cancelled => "cancelled",
                                    Outcome::Failed => "failed",
                                }
                                .into(),
                            ),
                        );
                        o
                    })
                    .collect(),
            ),
        );
        j.set(
            "health",
            Json::Arr(
                self.health
                    .iter()
                    .map(|h| {
                        let mut o = Json::obj();
                        o.set("at_ms", Json::Num(h.at_ms));
                        o.set("worker", Json::Num(h.worker as f64));
                        o.set("kind", Json::Str(h.kind_label().into()));
                        o.set("detail", Json::Str(h.detail()));
                        o
                    })
                    .collect(),
            ),
        );
        j
    }
}

/// Round continuous loads to integers with largest-remainder correction;
/// drops zero entries and guarantees `Σ ≥ l_rows + 1` (decode needs any
/// `L`, redundancy keeps the system coded).
pub fn round_loads(loads: &[f64], l_rows: usize) -> Vec<usize> {
    let mut out: Vec<usize> = loads.iter().map(|&l| l.floor() as usize).collect();
    let target = (loads.iter().sum::<f64>().round() as usize).max(l_rows + 1);
    let mut rem: Vec<(usize, f64)> = loads
        .iter()
        .enumerate()
        .map(|(i, &l)| (i, l - l.floor()))
        .collect();
    rem.sort_by(|a, b| b.1.total_cmp(&a.1));
    let mut total: usize = out.iter().sum();
    let mut k = 0;
    while total < target {
        out[rem[k % rem.len()].0] += 1;
        total += 1;
        k += 1;
    }
    out
}

/// One master-task prepared for dispatch: MDS code + ground truth + the
/// delay-sampled sub-tasks, ready to be queued on the worker threads.
/// Shared by [`run_plan`] (one task per master) and [`run_stream`] (a
/// queue of tasks per master) so the encode/dispatch semantics cannot
/// drift apart.
struct PreparedTask {
    code: MdsCode,
    truth: Vec<f64>,
    l_rows: usize,
    /// `(worker-queue index, sub-task)` pairs.
    subtasks: Vec<(usize, SubTask)>,
    /// Total coded rows dispatched.
    dispatched: usize,
    encode_wall_ms: f64,
}

/// Generate data, encode and delay-sample one master's task. `task_id`
/// is the id workers report back (`SubTask::master` — a flat per-job id
/// in stream mode); `deadline_offset` shifts every sampled delay (a
/// stream job's arrival time; 0 for one-shot runs). RNG consumption
/// order is the legacy `run_plan` order bit-for-bit: data, model
/// vector, MDS code, then one delay per dispatched entry.
#[allow(clippy::too_many_arguments)]
fn prepare_task(
    s: &Scenario,
    mp: &MasterPlan,
    uncoded: bool,
    m: usize,
    task_id: usize,
    cols: usize,
    backend: &Backend,
    deadline_offset: f64,
    rng: &mut Rng,
) -> anyhow::Result<PreparedTask> {
    let n_workers = s.n_workers();
    let l_rows = mp.l_rows as usize;
    anyhow::ensure!(
        l_rows > 0 && (mp.l_rows - l_rows as f64).abs() < 1e-9,
        "coordinator needs integer L_m"
    );
    // Data + model vector.
    let a: Vec<f32> = (0..l_rows * cols).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();
    // Direct product (f64 accumulation) for verification.
    let truth: Vec<f64> = (0..l_rows)
        .map(|i| {
            a[i * cols..(i + 1) * cols]
                .iter()
                .zip(&x)
                .map(|(&av, &xv)| av as f64 * xv as f64)
                .sum()
        })
        .collect();

    // Integer loads; the plan keeps entries ordered [local, workers…].
    let loads = round_loads(
        &mp.entries.iter().map(|e| e.load).collect::<Vec<_>>(),
        if uncoded { l_rows.saturating_sub(1) } else { l_rows },
    );
    let l_coded: usize = loads.iter().sum();
    let code = MdsCode::new(l_rows, l_coded, rng);

    // Encode: Ã = G·A through the backend. Fault injection targets
    // worker compute only; the master's encode is assumed reliable (as
    // in the paper's model).
    let g32: Vec<f32> = code.generator().data().iter().map(|&v| v as f32).collect();
    let t0 = Instant::now();
    let coded: Vec<f32> = match backend {
        Backend::Pjrt(h) => h.encode(g32, l_coded, l_rows, a.clone(), cols)?,
        Backend::Native | Backend::Flaky { .. } => {
            native_matmul(&g32, l_coded, l_rows, &a, cols)
        }
    };
    let encode_wall_ms = t0.elapsed().as_secs_f64() * 1e3;

    // Split into per-entry row blocks and sample each entry's delay.
    // Family-aware injection: shifted-exp links sample the legacy
    // eq.-(3) draws bit-for-bit, other families through the same
    // DelayFamily interface as the Monte-Carlo engine.
    let x_arc = Arc::new(x);
    let mut subtasks = Vec::new();
    let mut start = 0usize;
    let mut dispatched = 0usize;
    for (e, &l_int) in mp.entries.iter().zip(&loads) {
        if l_int == 0 {
            continue;
        }
        let delay = s.link_delay(m, e.node, l_int as f64, e.k, e.b).sample(rng);
        let a_block = coded[start * cols..(start + l_int) * cols].to_vec();
        let queue_idx = if e.node == 0 { n_workers + m } else { e.node - 1 };
        subtasks.push((
            queue_idx,
            SubTask {
                master: task_id,
                coded_start: start,
                rows: l_int,
                cols,
                a_block,
                x: Arc::clone(&x_arc),
                delay_ms: deadline_offset + delay,
            },
        ));
        start += l_int;
        dispatched += l_int;
    }
    Ok(PreparedTask {
        code,
        truth,
        l_rows,
        subtasks,
        dispatched,
        encode_wall_ms,
    })
}

/// Per-task result accumulator shared by both runtimes — and by both
/// transports (the TCP dispatcher in [`crate::net::transport`] feeds the
/// same collectors): coded-row arrivals in, completion decision out.
pub(crate) struct TaskCollector {
    /// (coded row, value) in arrival order.
    received: Vec<(usize, f64)>,
    rows_got: usize,
    /// Largest VIRTUAL delay among counted arrivals. Wall-clock publish
    /// order is deadline + real compute time, so it does not track
    /// virtual-delay order; the completion instant is the max virtual
    /// delay over the rows decode consumed.
    max_delay_ms: f64,
    completion: Option<f64>,
    l_rows: usize,
}

impl TaskCollector {
    fn new(l_rows: usize, t0_ms: f64) -> Self {
        Self {
            received: Vec::new(),
            rows_got: 0,
            max_delay_ms: t0_ms,
            completion: None,
            l_rows,
        }
    }

    /// Absorb one worker result; `true` exactly when this arrival
    /// completed the task (the caller fires cancellation). Arrivals
    /// after completion are dropped (already cancelled).
    pub(crate) fn absorb(&mut self, r: &WorkerResult) -> bool {
        if self.completion.is_some() {
            return false;
        }
        for (i, &v) in r.values.iter().enumerate() {
            self.received.push((r.coded_start + i, v as f64));
        }
        self.rows_got += r.rows;
        self.max_delay_ms = self.max_delay_ms.max(r.delay_ms);
        if self.rows_got >= self.l_rows {
            // Completion = slowest virtual delay among the rows decode
            // consumed (publish order is wall-clock and may differ).
            self.completion = Some(self.max_delay_ms);
            true
        } else {
            false
        }
    }

    fn complete(&self) -> bool {
        self.rows_got >= self.l_rows
    }

    /// Decode consumes exactly L rows; arrivals past that (landed
    /// before cancellation took hold) are not "used".
    fn rows_used(&self) -> usize {
        self.rows_got.min(self.l_rows)
    }
}

/// The dispatch half both runtimes share, generalized over transports:
/// route every queue to its worker (in-process thread or TCP peer),
/// feed every [`WorkerResult`] to `collectors[result.master]` —
/// cancelling that task's remaining redundancy the moment it completes
/// — then join/drain. Returns per-worker computed/skipped counts, the
/// event log and the wall time (ms). One seam for one-shot and stream,
/// thread and socket: the completion/cancellation semantics cannot
/// drift between any of the four combinations.
#[allow(clippy::type_complexity)]
fn dispatch_and_collect(
    queues: Vec<Vec<SubTask>>,
    collectors: &mut [TaskCollector],
    backend: &Backend,
    time_scale: f64,
    transport: &Transport,
    fault: Option<&FaultPlan>,
    health: &HealthConfig,
) -> anyhow::Result<(Vec<usize>, Vec<usize>, Vec<TaskEvent>, f64, Vec<HealthEvent>)> {
    match transport {
        Transport::Thread => {
            dispatch_threads(queues, collectors, backend, time_scale, fault)
        }
        Transport::Tcp(opts) => {
            crate::net::transport::dispatch_tcp(
                queues, collectors, opts, time_scale, fault, health,
            )
        }
    }
}

/// The in-process transport: one worker thread per non-empty queue, an
/// mpsc results bus, cancellation via shared atomics. Fault injection
/// resolves the plan to per-worker trigger indices; a crashed thread
/// simply stops producing (its redundancy absorbs the loss — there is
/// no re-queue in thread mode, only a [`HealthEventKind::Disconnect`]
/// record so the report shows what happened).
#[allow(clippy::type_complexity)]
fn dispatch_threads(
    queues: Vec<Vec<SubTask>>,
    collectors: &mut [TaskCollector],
    backend: &Backend,
    time_scale: f64,
    fault: Option<&FaultPlan>,
) -> anyhow::Result<(Vec<usize>, Vec<usize>, Vec<TaskEvent>, f64, Vec<HealthEvent>)> {
    let cancel: Arc<Vec<AtomicBool>> = Arc::new(
        (0..collectors.len()).map(|_| AtomicBool::new(false)).collect(),
    );
    let (res_tx, res_rx) = channel::<WorkerResult>();
    let t_start = Instant::now();
    let mut join = Vec::new();
    let mut worker_computed = vec![0usize; queues.len()];
    let mut worker_skipped = vec![0usize; queues.len()];
    for (wid, tasks) in queues.into_iter().enumerate() {
        if tasks.is_empty() {
            continue;
        }
        let backend = backend.clone();
        let cancel = Arc::clone(&cancel);
        let tx = res_tx.clone();
        let faults = fault
            .map(|p| p.for_worker(wid, tasks.len()))
            .unwrap_or_default();
        join.push((
            wid,
            std::thread::Builder::new()
                .name(format!("worker-{wid}"))
                .spawn(move || {
                    worker::run_worker(
                        wid, tasks, backend, cancel, tx, time_scale, t_start, &faults,
                    )
                })?,
        ));
    }
    drop(res_tx);
    while let Ok(r) = res_rx.recv() {
        if collectors[r.master].absorb(&r) {
            cancel[r.master].store(true, Ordering::SeqCst);
        }
    }
    let mut events: Vec<TaskEvent> = Vec::new();
    let mut health: Vec<HealthEvent> = Vec::new();
    for (wid, h) in join {
        let (computed, skipped, ev, crashed) = h.join().expect("worker panicked");
        worker_computed[wid] = computed;
        worker_skipped[wid] = skipped;
        events.extend(ev);
        if crashed {
            health.push(HealthEvent {
                at_ms: t_start.elapsed().as_secs_f64() * 1e3,
                worker: wid,
                kind: HealthEventKind::Disconnect,
            });
        }
    }
    Ok((
        worker_computed,
        worker_skipped,
        events,
        t_start.elapsed().as_secs_f64() * 1e3,
        health,
    ))
}

/// Max relative decode error of a completed task against the direct
/// product — the verify metric shared by both runtimes. Relative,
/// because the LU decode of an L×L Gaussian sub-generator amplifies
/// f32 rounding with L.
fn decode_rel_err(code: &MdsCode, received: &[(usize, f64)], truth: &[f64]) -> f64 {
    let z = code
        .decode(received)
        .expect("any L rows decode (Gaussian parity)");
    z.iter()
        .zip(truth)
        .map(|(a, b)| (a - b).abs() / (1.0 + b.abs()))
        .fold(0.0, f64::max)
}

/// Plan + run the coordinator end-to-end. Returns the per-master reports.
pub fn run(cfg: &CoordinatorConfig) -> anyhow::Result<Report> {
    let plan: Plan = plan::build(&cfg.scenario, &cfg.spec);
    run_plan(
        &cfg.scenario,
        &plan,
        &RunOptions {
            cols: cfg.cols,
            time_scale: cfg.time_scale,
            backend: cfg.backend.clone(),
            seed: cfg.seed,
            verify: cfg.verify,
            transport: Transport::Thread,
            fault: None,
            health: HealthConfig::default(),
        },
    )
}

/// Deploy an existing [`Plan`] (however it was built or deserialized) on
/// the real multi-threaded runtime. This is the coordinator half of the
/// unified [`crate::exec::Executor`] seam.
pub fn run_plan(s: &Scenario, plan: &Plan, opts: &RunOptions) -> anyhow::Result<Report> {
    let m_cnt = s.n_masters();
    let n_workers = s.n_workers();
    let mut rng = Rng::new(opts.seed);

    // ---- Per-master data, codes and sub-task construction -------------
    // Static per-master facts; the arrival/completion state lives in the
    // shared [`TaskCollector`]s.
    struct MasterMeta {
        code: MdsCode,
        truth: Vec<f64>,
        t_est: f64,
        encode_wall_ms: f64,
        total_dispatched: usize,
    }

    let mut metas: Vec<MasterMeta> = Vec::with_capacity(m_cnt);
    let mut collectors: Vec<TaskCollector> = Vec::with_capacity(m_cnt);
    // Sub-task queues: one per worker thread; local processing of master m
    // runs on its own thread (index n_workers + m).
    let mut queues: Vec<Vec<SubTask>> =
        (0..n_workers + m_cnt).map(|_| Vec::new()).collect();

    for (m, mp) in plan.masters.iter().enumerate() {
        let prep = prepare_task(
            s,
            mp,
            plan.uncoded,
            m,
            m,
            opts.cols,
            &opts.backend,
            0.0,
            &mut rng,
        )?;
        for (queue_idx, t) in prep.subtasks {
            queues[queue_idx].push(t);
        }
        collectors.push(TaskCollector::new(prep.l_rows, 0.0));
        metas.push(MasterMeta {
            code: prep.code,
            truth: prep.truth,
            t_est: mp.t_est,
            encode_wall_ms: prep.encode_wall_ms,
            total_dispatched: prep.dispatched,
        });
    }

    let (worker_computed, worker_skipped, events, wall_ms, health) = dispatch_and_collect(
        queues,
        &mut collectors,
        &opts.backend,
        opts.time_scale,
        &opts.transport,
        opts.fault.as_ref(),
        &opts.health,
    )?;

    // ---- Decode + verify -------------------------------------------------
    let masters = metas
        .into_iter()
        .zip(collectors)
        .map(|(meta, col)| {
            let max_rel_err = (opts.verify && col.complete())
                .then(|| decode_rel_err(&meta.code, &col.received, &meta.truth));
            MasterReport {
                completion_ms: col.completion.unwrap_or(f64::INFINITY),
                t_est_ms: meta.t_est,
                rows_used: col.rows_used(),
                rows_cancelled: meta.total_dispatched.saturating_sub(col.rows_got),
                max_rel_err,
                encode_wall_ms: meta.encode_wall_ms,
            }
        })
        .collect();

    Ok(Report {
        label: plan.label.clone(),
        masters,
        wall_ms,
        worker_computed,
        worker_skipped,
        events,
        health,
    })
}

/// Options for [`run_stream`]: a queue of `jobs` tasks per master,
/// arriving every `period_ms` of virtual time, all dispatched over ONE
/// long-lived set of worker threads (the shared pool of the serving
/// story — no per-job thread spawning).
#[derive(Clone)]
pub struct StreamOptions {
    /// Jobs per master.
    pub jobs: usize,
    /// Virtual inter-arrival per master (ms).
    pub period_ms: f64,
    /// Task width `S_m`.
    pub cols: usize,
    /// Wall-clock seconds per virtual millisecond.
    pub time_scale: f64,
    pub backend: Backend,
    pub seed: u64,
    pub verify: bool,
    /// How sub-tasks reach workers: in-process threads (default) or TCP.
    pub transport: Transport,
    /// Injected faults (see [`RunOptions::fault`]).
    pub fault: Option<FaultPlan>,
    /// Heartbeat / breaker thresholds (see [`RunOptions::health`]).
    pub health: HealthConfig,
}

/// One streamed job's outcome on the real runtime.
#[derive(Clone, Debug)]
pub struct JobOutcome {
    pub master: usize,
    pub job: usize,
    pub arrival_ms: f64,
    /// Absolute virtual completion (∞ if the job never decoded).
    pub completion_ms: f64,
    pub rows_used: usize,
    pub max_rel_err: Option<f64>,
}

impl JobOutcome {
    /// Arrival → completion (the serving metric).
    pub fn sojourn_ms(&self) -> f64 {
        self.completion_ms - self.arrival_ms
    }
}

/// Deploy a QUEUE of jobs — `jobs` tasks per master, arriving every
/// `period_ms` — on the real multi-threaded runtime. Unlike
/// [`run_plan`] (one task per master, fresh threads per call), the
/// whole stream shares one set of worker threads: every worker receives
/// all of its sub-tasks across all jobs up front (absolute virtual
/// deadlines = arrival + sampled delay) and serves them in deadline
/// order, while the collector decodes each `(master, job)` pair
/// independently and cancels its redundancy. Queueing *between* jobs of
/// one master is open-loop here (arrivals don't wait for completions) —
/// the closed-loop FIFO semantics live in the virtual-time serving
/// layer ([`crate::serve`]); this is its executable counterpart for
/// real encode/compute/decode streams.
pub fn run_stream(s: &Scenario, plan: &Plan, opts: &StreamOptions) -> anyhow::Result<Vec<JobOutcome>> {
    let m_cnt = s.n_masters();
    let n_workers = s.n_workers();
    anyhow::ensure!(opts.jobs > 0, "run_stream needs ≥ 1 job per master");
    anyhow::ensure!(
        opts.period_ms.is_finite() && opts.period_ms >= 0.0,
        "period_ms must be finite and ≥ 0"
    );
    let mut rng = Rng::new(opts.seed);

    struct JobMeta {
        code: MdsCode,
        truth: Vec<f64>,
        arrival_ms: f64,
    }

    // Flat id = job * m_cnt + master; worker queues span the stream.
    let mut metas: Vec<JobMeta> = Vec::with_capacity(m_cnt * opts.jobs);
    let mut collectors: Vec<TaskCollector> = Vec::with_capacity(m_cnt * opts.jobs);
    let mut queues: Vec<Vec<SubTask>> =
        (0..n_workers + m_cnt).map(|_| Vec::new()).collect();

    for job in 0..opts.jobs {
        let arrival = job as f64 * opts.period_ms;
        for (m, mp) in plan.masters.iter().enumerate() {
            // Flat (job, master) id: the worker threads and the
            // cancellation flags are per-job-per-master; the arrival
            // offset makes deadlines absolute across the stream.
            let flat = job * m_cnt + m;
            let prep = prepare_task(
                s,
                mp,
                plan.uncoded,
                m,
                flat,
                opts.cols,
                &opts.backend,
                arrival,
                &mut rng,
            )?;
            for (queue_idx, t) in prep.subtasks {
                queues[queue_idx].push(t);
            }
            collectors.push(TaskCollector::new(prep.l_rows, arrival));
            metas.push(JobMeta {
                code: prep.code,
                truth: prep.truth,
                arrival_ms: arrival,
            });
        }
    }

    let (_computed, _skipped, _events, _wall_ms, _health) = dispatch_and_collect(
        queues,
        &mut collectors,
        &opts.backend,
        opts.time_scale,
        &opts.transport,
        opts.fault.as_ref(),
        &opts.health,
    )?;

    Ok(metas
        .into_iter()
        .zip(collectors)
        .enumerate()
        .map(|(flat, (meta, col))| {
            let max_rel_err = (opts.verify && col.complete())
                .then(|| decode_rel_err(&meta.code, &col.received, &meta.truth));
            JobOutcome {
                master: flat % m_cnt,
                job: flat / m_cnt,
                arrival_ms: meta.arrival_ms,
                completion_ms: col.completion.unwrap_or(f64::INFINITY),
                rows_used: col.rows_used(),
                max_rel_err,
            }
        })
        .collect())
}

/// Naive f32 matmul fallback (row-major).
pub fn native_matmul(a: &[f32], r: usize, k: usize, b: &[f32], c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * c..(kk + 1) * c];
            let orow = &mut out[i * c..(i + 1) * c];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assign::ValueModel;
    use crate::config::{AShift, CommModel};
    use crate::plan::{LoadMethod, Policy};

    fn tiny_scenario(seed: u64) -> Scenario {
        Scenario::random(
            "coordinator-test",
            2,
            4,
            256.0, // L_m = 256 rows
            AShift::Range(0.01, 0.05),
            2.0,
            CommModel::Stochastic,
            seed,
        )
    }

    fn cfg(seed: u64) -> CoordinatorConfig {
        CoordinatorConfig {
            scenario: tiny_scenario(seed),
            spec: PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Markov,
                loads: LoadMethod::Markov,
            },
            cols: 64,
            // Speed virtual time up 50×: delays of ~10 ms virtual become
            // ~0.2 ms wall — the test completes fast but ordering holds.
            time_scale: 2e-5,
            backend: Backend::Native,
            seed,
            verify: true,
        }
    }

    #[test]
    fn end_to_end_recovers_products() {
        let report = run(&cfg(1)).unwrap();
        assert_eq!(report.masters.len(), 2);
        for (m, mr) in report.masters.iter().enumerate() {
            assert!(
                mr.completion_ms.is_finite(),
                "master {m} never completed"
            );
            let err = mr.max_rel_err.expect("verified");
            assert!(err < 1e-3, "master {m} decode error {err}");
        }
    }

    #[test]
    fn cancellation_saves_work() {
        // With 2× Markov redundancy, some coded rows must be cancelled.
        // Cancellation is inherently racy at compressed time scales
        // (every deadline fires within a few hundred µs), so this test
        // runs closer to real time: deadlines are spread over tens of ms
        // and the collector reacts within µs.
        let mut c = cfg(2);
        c.scenario = Scenario::random(
            "coordinator-cancel",
            2,
            10,
            256.0,
            AShift::Range(0.01, 0.2), // wide spread of node speeds
            2.0,
            CommModel::Stochastic,
            2,
        );
        c.time_scale = 2e-3; // 1 virtual ms = 2 wall ms
        let report = run(&c).unwrap();
        let skipped: usize = report.worker_skipped.iter().sum();
        let cancelled: usize = report.masters.iter().map(|m| m.rows_cancelled).sum();
        assert!(
            skipped > 0 || cancelled > 0,
            "expected some cancelled redundancy: {report:?}"
        );
        assert!(report.all_verified(1e-3));
    }

    #[test]
    fn fractional_policy_runs() {
        let mut c = cfg(3);
        c.spec.policy = Policy::Frac;
        let report = run(&c).unwrap();
        assert!(report.all_verified(1e-3), "{report:?}");
    }

    #[test]
    fn uncoded_policy_runs_without_redundancy() {
        let mut c = cfg(4);
        c.spec.policy = Policy::UncodedUniform;
        let report = run(&c).unwrap();
        for mr in &report.masters {
            assert!(mr.completion_ms.is_finite());
            // Uncoded: nothing can be cancelled (all rows needed)...
            assert_eq!(mr.rows_cancelled, 0, "{report:?}");
        }
    }

    #[test]
    fn completion_tracks_planner_estimate() {
        // Virtual completion should be the same order of magnitude as the
        // planner's t* (single realization: generous bounds).
        let report = run(&cfg(5)).unwrap();
        for mr in &report.masters {
            assert!(
                mr.completion_ms < 5.0 * mr.t_est_ms + 50.0,
                "completion {} ≫ estimate {}",
                mr.completion_ms,
                mr.t_est_ms
            );
        }
    }

    #[test]
    fn failed_computations_absorbed_by_code() {
        // Fault injection: every 5th worker compute fails. The Markov
        // plan carries 2× redundancy (tolerates up to 50% load loss), so
        // masters must still decode and verify — failures behave like
        // stragglers that never return.
        let mut c = cfg(7);
        c.scenario = Scenario::random(
            "coordinator-faults",
            2,
            12,
            256.0,
            AShift::Range(0.01, 0.05),
            2.0,
            CommModel::Stochastic,
            7,
        );
        c.backend = Backend::flaky(5);
        let report = run(&c).unwrap();
        assert!(
            report.all_verified(1e-3),
            "decode must survive injected faults: {report:?}"
        );
        // And faults actually happened.
        let skipped: usize = report.worker_skipped.iter().sum();
        assert!(skipped > 0, "no faults were injected? {report:?}");
    }

    #[test]
    fn total_fault_of_one_worker_tolerated() {
        // Kill one entire worker (all its computes fail) by making the
        // scenario tiny enough that the flaky counter lines up — instead,
        // simpler: run with every=2 (half of all computes fail). With 2×
        // redundancy the system still completes most of the time; assert
        // at least that nothing panics and reports are well-formed.
        let mut c = cfg(8);
        c.backend = Backend::flaky(2);
        let report = run(&c).unwrap();
        assert_eq!(report.masters.len(), 2);
        for mr in &report.masters {
            // Completion may be ∞ if too many faults hit one master —
            // the report must still be coherent.
            assert!(mr.rows_cancelled + mr.rows_used <= 3 * 256);
        }
    }

    #[test]
    fn report_json_export_is_consistent() {
        let report = run(&cfg(9)).unwrap();
        let j = report.to_json();
        let text = j.to_string_pretty();
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(
            back.get("label").and_then(|v| v.as_str()),
            Some(report.label.as_str())
        );
        let events = back.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), report.events.len());
        // computed rows in events == rows the masters received
        let computed_rows: f64 = report
            .events
            .iter()
            .filter(|e| e.outcome == Outcome::Computed)
            .map(|e| e.rows as f64)
            .sum();
        let received: f64 = report
            .masters
            .iter()
            .map(|m| m.rows_used as f64)
            .sum();
        assert!(computed_rows >= received);
        assert!(report.saved_fraction() >= 0.0 && report.saved_fraction() < 1.0);
    }

    #[test]
    fn job_stream_shares_worker_threads_and_decodes_every_job() {
        // Queued-job dispatch: 3 jobs per master arrive over virtual
        // time and run on ONE long-lived worker-thread set; every
        // (master, job) pair must decode and verify independently.
        let s = Scenario::random(
            "stream-test",
            2,
            4,
            64.0,
            AShift::Range(0.01, 0.05),
            2.0,
            CommModel::Stochastic,
            11,
        );
        let p = plan::build(
            &s,
            &PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Markov,
                loads: LoadMethod::Markov,
            },
        );
        let outs = run_stream(
            &s,
            &p,
            &StreamOptions {
                jobs: 3,
                period_ms: 5.0,
                cols: 8,
                time_scale: 2e-5,
                backend: Backend::Native,
                seed: 11,
                verify: true,
                transport: Transport::Thread,
                fault: None,
                health: HealthConfig::default(),
            },
        )
        .unwrap();
        assert_eq!(outs.len(), 6);
        for o in &outs {
            assert!(o.completion_ms.is_finite(), "{o:?}");
            assert_eq!(o.arrival_ms, o.job as f64 * 5.0);
            assert!(o.sojourn_ms() > 0.0, "{o:?}");
            assert_eq!(o.rows_used, 64);
            let err = o.max_rel_err.expect("verified");
            assert!(err < 1e-3, "job ({}, {}) decode error {err}", o.master, o.job);
        }
        // Outcomes are flat-ordered (job-major, master-minor).
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.job, i / 2);
            assert_eq!(o.master, i % 2);
        }
    }

    #[test]
    fn round_loads_properties() {
        let loads = [3.6, 2.2, 0.4, 5.8];
        let out = round_loads(&loads, 10);
        assert_eq!(out.iter().sum::<usize>(), 12.max(11));
        // order-preserving, near each input
        for (o, l) in out.iter().zip(&loads) {
            assert!((*o as f64 - l).abs() <= 1.0 + 1e-9);
        }
        // guarantee: Σ ≥ L + 1
        let out2 = round_loads(&[0.5, 0.5], 3);
        assert!(out2.iter().sum::<usize>() >= 4);
    }
}
