//! Worker thread: deadline-scheduled sub-task execution with
//! cancellation.
//!
//! Each worker owns the sub-tasks the plan routed to it. Delays were
//! sampled at dispatch (they encode the comm + shift + comp legs AND the
//! processor-sharing stretch 1/k, 1/b); the worker sorts by deadline and,
//! at each deadline: skips if the master already decoded (cancellation),
//! otherwise executes the real mat-vec through the backend and publishes
//! the coded products.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::Backend;

/// One coded row-block assigned to a worker.
pub struct SubTask {
    pub master: usize,
    /// First coded-row index of this block in the master's Ã.
    pub coded_start: usize,
    pub rows: usize,
    pub cols: usize,
    /// Row-major (rows × cols) coded block.
    pub a_block: Vec<f32>,
    /// Shared model vector (cols).
    pub x: Arc<Vec<f32>>,
    /// Sampled virtual delay (ms) until this block's results arrive.
    pub delay_ms: f64,
}

/// Computed products for one sub-task.
pub struct WorkerResult {
    pub master: usize,
    pub coded_start: usize,
    pub rows: usize,
    pub values: Vec<f32>,
    pub delay_ms: f64,
    pub worker: usize,
}

/// Execute one sub-task's mat-vec on the chosen backend.
pub fn compute(backend: &Backend, t: &SubTask) -> anyhow::Result<Vec<f32>> {
    match backend {
        Backend::Pjrt(h) => h.matvec(
            t.a_block.clone(),
            t.rows,
            t.cols,
            t.x.as_ref().clone(),
            1,
        ),
        Backend::Native => Ok(super::native_matmul(
            &t.a_block, t.rows, t.cols, &t.x, 1,
        )),
        Backend::Flaky { every } => {
            // Schedule-independent fault choice: hash the sub-task id.
            let h = t
                .master
                .wrapping_mul(0x9E37_79B9)
                .wrapping_add(t.coded_start.wrapping_mul(0x85EB_CA6B));
            if (h >> 4) % every == 0 {
                anyhow::bail!(
                    "injected fault on sub-task (m={}, start={})",
                    t.master,
                    t.coded_start
                );
            }
            Ok(super::native_matmul(&t.a_block, t.rows, t.cols, &t.x, 1))
        }
    }
}

/// Marker trait alias documenting what workers need from a backend.
pub trait Compute: Send {}

/// What happened to one sub-task (observability / metrics export).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Computed and published.
    Computed,
    /// Skipped — its master had already decoded (cancellation).
    Cancelled,
    /// Backend failure (behaves like a straggler that never returns).
    Failed,
}

/// Per-sub-task event record.
#[derive(Clone, Copy, Debug)]
pub struct TaskEvent {
    pub worker: usize,
    pub master: usize,
    pub rows: usize,
    /// Sampled virtual deadline (ms).
    pub deadline_ms: f64,
    /// Wall-clock spent in the backend compute call (ms; 0 if skipped).
    pub compute_wall_ms: f64,
    pub outcome: Outcome,
}

/// Worker main loop. Returns `(computed, skipped, events)`.
pub fn run_worker(
    wid: usize,
    mut tasks: Vec<SubTask>,
    backend: Backend,
    cancel: Arc<Vec<AtomicBool>>,
    tx: Sender<WorkerResult>,
    time_scale: f64,
    t_start: Instant,
) -> (usize, usize, Vec<TaskEvent>) {
    // Deadline order = arrival order under processor sharing. total_cmp:
    // deadlines are sums of finite sampled delays plus arrival offsets,
    // but a long-lived serving loop must not be one NaN away from a
    // worker-thread panic.
    tasks.sort_by(|a, b| a.delay_ms.total_cmp(&b.delay_ms));
    let mut computed = 0usize;
    let mut skipped = 0usize;
    let mut events = Vec::with_capacity(tasks.len());
    for t in tasks {
        // Sleep until this sub-task's virtual deadline.
        let deadline = t_start + Duration::from_secs_f64(t.delay_ms * time_scale);
        let now = Instant::now();
        if deadline > now {
            std::thread::sleep(deadline - now);
        }
        let mut event = TaskEvent {
            worker: wid,
            master: t.master,
            rows: t.rows,
            deadline_ms: t.delay_ms,
            compute_wall_ms: 0.0,
            outcome: Outcome::Cancelled,
        };
        if cancel[t.master].load(Ordering::SeqCst) {
            skipped += 1;
            events.push(event);
            continue;
        }
        let c0 = Instant::now();
        match compute(&backend, &t) {
            Ok(values) => {
                event.compute_wall_ms = c0.elapsed().as_secs_f64() * 1e3;
                event.outcome = Outcome::Computed;
                computed += 1;
                let _ = tx.send(WorkerResult {
                    master: t.master,
                    coded_start: t.coded_start,
                    rows: t.rows,
                    values,
                    delay_ms: t.delay_ms,
                    worker: wid,
                });
            }
            Err(e) => {
                // A failed compute behaves like a straggler that never
                // returns: the MDS redundancy absorbs it. Log and go on.
                eprintln!("worker {wid}: compute failed: {e}");
                event.compute_wall_ms = c0.elapsed().as_secs_f64() * 1e3;
                event.outcome = Outcome::Failed;
                skipped += 1;
            }
        }
        events.push(event);
    }
    (computed, skipped, events)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn mk_task(master: usize, start: usize, rows: usize, delay: f64) -> SubTask {
        let cols = 8;
        SubTask {
            master,
            coded_start: start,
            rows,
            cols,
            a_block: vec![1.0; rows * cols],
            x: Arc::new(vec![2.0; cols]),
            delay_ms: delay,
        }
    }

    #[test]
    fn emits_in_deadline_order() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false)]);
        let tasks = vec![
            mk_task(0, 10, 2, 5.0),
            mk_task(0, 0, 2, 1.0),
            mk_task(0, 20, 2, 3.0),
        ];
        let (computed, skipped, events) = run_worker(
            7,
            tasks,
            Backend::Native,
            cancel,
            tx,
            1e-5, // fast
            Instant::now(),
        );
        assert_eq!((computed, skipped), (3, 0));
        assert_eq!(events.len(), 3);
        assert!(events.iter().all(|e| e.outcome == Outcome::Computed));
        // events sorted by deadline
        assert!(events.windows(2).all(|w| w[0].deadline_ms <= w[1].deadline_ms));
        let order: Vec<usize> = rx.iter().map(|r| r.coded_start).collect();
        assert_eq!(order, vec![0, 20, 10]);
    }

    #[test]
    fn computes_correct_products() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false)]);
        run_worker(
            0,
            vec![mk_task(0, 0, 3, 0.1)],
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
        );
        let r = rx.recv().unwrap();
        // row of ones (len 8) · vector of twos = 16
        assert_eq!(r.values, vec![16.0, 16.0, 16.0]);
        assert_eq!(r.worker, 0);
    }

    #[test]
    fn cancellation_skips_remaining() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(true)]); // already done
        let (computed, skipped, events) = run_worker(
            0,
            vec![mk_task(0, 0, 2, 0.1), mk_task(0, 2, 2, 0.2)],
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
        );
        assert_eq!((computed, skipped), (0, 2));
        assert!(events.iter().all(|e| e.outcome == Outcome::Cancelled));
        assert!(rx.recv().is_err(), "nothing should be emitted");
    }
}
