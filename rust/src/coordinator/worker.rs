//! Worker thread: deadline-scheduled sub-task execution with
//! cancellation.
//!
//! Each worker owns the sub-tasks the plan routed to it. Delays were
//! sampled at dispatch (they encode the comm + shift + comp legs AND the
//! processor-sharing stretch 1/k, 1/b); the worker sorts by deadline and,
//! at each deadline: skips if the master already decoded (cancellation),
//! otherwise executes the real mat-vec through the backend and publishes
//! the coded products.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::Backend;
use crate::health::WorkerFaults;

/// A gray-failed worker parks on its cancel flags in this granularity…
const GRAY_POLL: Duration = Duration::from_millis(2);
/// …but never longer than this (a liveness backstop for thread-mode
/// runs where nothing external will ever shut the worker down).
const GRAY_PARK_CAP: Duration = Duration::from_secs(30);

/// One coded row-block assigned to a worker.
pub struct SubTask {
    pub master: usize,
    /// First coded-row index of this block in the master's Ã.
    pub coded_start: usize,
    pub rows: usize,
    pub cols: usize,
    /// Row-major (rows × cols) coded block.
    pub a_block: Vec<f32>,
    /// Shared model vector (cols).
    pub x: Arc<Vec<f32>>,
    /// Sampled virtual delay (ms) until this block's results arrive.
    pub delay_ms: f64,
}

/// Computed products for one sub-task.
pub struct WorkerResult {
    pub master: usize,
    pub coded_start: usize,
    pub rows: usize,
    pub values: Vec<f32>,
    pub delay_ms: f64,
    pub worker: usize,
}

/// Execute one sub-task's mat-vec on the chosen backend.
pub fn compute(backend: &Backend, t: &SubTask) -> anyhow::Result<Vec<f32>> {
    match backend {
        Backend::Pjrt(h) => h.matvec(
            t.a_block.clone(),
            t.rows,
            t.cols,
            t.x.as_ref().clone(),
            1,
        ),
        Backend::Native => Ok(super::native_matmul(
            &t.a_block, t.rows, t.cols, &t.x, 1,
        )),
        Backend::Flaky { every } => {
            // Schedule-independent fault choice: hash the sub-task id.
            let h = t
                .master
                .wrapping_mul(0x9E37_79B9)
                .wrapping_add(t.coded_start.wrapping_mul(0x85EB_CA6B));
            if (h >> 4) % every == 0 {
                anyhow::bail!(
                    "injected fault on sub-task (m={}, start={})",
                    t.master,
                    t.coded_start
                );
            }
            Ok(super::native_matmul(&t.a_block, t.rows, t.cols, &t.x, 1))
        }
    }
}

/// Marker trait alias documenting what workers need from a backend.
pub trait Compute: Send {}

/// What happened to one sub-task (observability / metrics export).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Computed and published.
    Computed,
    /// Skipped — its master had already decoded (cancellation).
    Cancelled,
    /// Backend failure (behaves like a straggler that never returns).
    Failed,
}

/// Per-sub-task event record.
#[derive(Clone, Copy, Debug)]
pub struct TaskEvent {
    pub worker: usize,
    pub master: usize,
    pub rows: usize,
    /// Sampled virtual deadline (ms).
    pub deadline_ms: f64,
    /// Wall-clock spent in the backend compute call (ms; 0 if skipped).
    pub compute_wall_ms: f64,
    pub outcome: Outcome,
}

/// Worker main loop. Returns `(computed, skipped, events, crashed)` —
/// `crashed` is true only when an injected [`WorkerFaults::crash_at`]
/// fired, so callers can simulate the process dying (sever the socket)
/// rather than draining cleanly.
pub fn run_worker(
    wid: usize,
    mut tasks: Vec<SubTask>,
    backend: Backend,
    cancel: Arc<Vec<AtomicBool>>,
    tx: Sender<WorkerResult>,
    time_scale: f64,
    t_start: Instant,
    faults: &WorkerFaults,
) -> (usize, usize, Vec<TaskEvent>, bool) {
    // Deadline order = arrival order under processor sharing. total_cmp:
    // deadlines are sums of finite sampled delays plus arrival offsets,
    // but a long-lived serving loop must not be one NaN away from a
    // worker-thread panic.
    tasks.sort_by(|a, b| a.delay_ms.total_cmp(&b.delay_ms));
    let backend = match faults.flaky_every {
        Some(every) => Backend::Flaky { every },
        None => backend,
    };
    // No socket exists at this layer, so an injected connection drop
    // degenerates to a crash here; the net worker severs the stream
    // itself and strips `drop_at` before calling in.
    let crash_at = match (faults.crash_at, faults.drop_at) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    };
    let mut computed = 0usize;
    let mut skipped = 0usize;
    let mut events = Vec::with_capacity(tasks.len());
    for (i, t) in tasks.into_iter().enumerate() {
        if crash_at.is_some_and(|at| i >= at) {
            // The "process" dies here: remaining sub-tasks are lost
            // without a trace — detection and re-queue are the
            // coordinator's job.
            return (computed, skipped, events, true);
        }
        // Sleep until this sub-task's virtual deadline, plus any
        // injected degradation (spike from its trigger on, slow-start
        // until its trigger).
        let mut extra_ms = 0.0;
        if let Some((from, ms)) = faults.spike {
            if i >= from {
                extra_ms += ms;
            }
        }
        if let Some((until, ms)) = faults.slow {
            if i < until {
                extra_ms += ms;
            }
        }
        let deadline = t_start
            + Duration::from_secs_f64(t.delay_ms * time_scale + extra_ms * 1e-3);
        let now = Instant::now();
        if deadline > now {
            std::thread::sleep(deadline - now);
        }
        let mut event = TaskEvent {
            worker: wid,
            master: t.master,
            rows: t.rows,
            deadline_ms: t.delay_ms,
            compute_wall_ms: 0.0,
            outcome: Outcome::Cancelled,
        };
        if faults.gray_from.is_some_and(|from| i >= from) {
            // Gray failure: alive (beats keep flowing from the net
            // layer) but compute is dead. Park until the task is
            // cancelled — by redundancy completing the master or by the
            // coordinator shutting this worker down — with a wall-clock
            // backstop so a thread-mode run can never hang forever.
            let parked = Instant::now();
            while !cancel[t.master].load(Ordering::SeqCst) {
                if parked.elapsed() > GRAY_PARK_CAP {
                    break;
                }
                std::thread::sleep(GRAY_POLL);
            }
            event.outcome = if cancel[t.master].load(Ordering::SeqCst) {
                Outcome::Cancelled
            } else {
                Outcome::Failed
            };
            skipped += 1;
            events.push(event);
            continue;
        }
        if cancel[t.master].load(Ordering::SeqCst) {
            skipped += 1;
            events.push(event);
            continue;
        }
        let c0 = Instant::now();
        match compute(&backend, &t) {
            Ok(values) => {
                event.compute_wall_ms = c0.elapsed().as_secs_f64() * 1e3;
                event.outcome = Outcome::Computed;
                computed += 1;
                let _ = tx.send(WorkerResult {
                    master: t.master,
                    coded_start: t.coded_start,
                    rows: t.rows,
                    values,
                    delay_ms: t.delay_ms,
                    worker: wid,
                });
            }
            Err(e) => {
                // A failed compute behaves like a straggler that never
                // returns: the MDS redundancy absorbs it. Log and go on.
                eprintln!("worker {wid}: compute failed: {e}");
                event.compute_wall_ms = c0.elapsed().as_secs_f64() * 1e3;
                event.outcome = Outcome::Failed;
                skipped += 1;
            }
        }
        events.push(event);
    }
    (computed, skipped, events, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn mk_task(master: usize, start: usize, rows: usize, delay: f64) -> SubTask {
        let cols = 8;
        SubTask {
            master,
            coded_start: start,
            rows,
            cols,
            a_block: vec![1.0; rows * cols],
            x: Arc::new(vec![2.0; cols]),
            delay_ms: delay,
        }
    }

    #[test]
    fn emits_in_deadline_order() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false)]);
        let tasks = vec![
            mk_task(0, 10, 2, 5.0),
            mk_task(0, 0, 2, 1.0),
            mk_task(0, 20, 2, 3.0),
        ];
        let (computed, skipped, events, crashed) = run_worker(
            7,
            tasks,
            Backend::Native,
            cancel,
            tx,
            1e-5, // fast
            Instant::now(),
            &WorkerFaults::none(),
        );
        assert!(!crashed);
        assert_eq!((computed, skipped), (3, 0));
        assert_eq!(events.len(), 3);
        assert!(events.iter().all(|e| e.outcome == Outcome::Computed));
        // events sorted by deadline
        assert!(events.windows(2).all(|w| w[0].deadline_ms <= w[1].deadline_ms));
        let order: Vec<usize> = rx.iter().map(|r| r.coded_start).collect();
        assert_eq!(order, vec![0, 20, 10]);
    }

    #[test]
    fn computes_correct_products() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false)]);
        run_worker(
            0,
            vec![mk_task(0, 0, 3, 0.1)],
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
            &WorkerFaults::none(),
        );
        let r = rx.recv().unwrap();
        // row of ones (len 8) · vector of twos = 16
        assert_eq!(r.values, vec![16.0, 16.0, 16.0]);
        assert_eq!(r.worker, 0);
    }

    #[test]
    fn cancellation_skips_remaining() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(true)]); // already done
        let (computed, skipped, events, _) = run_worker(
            0,
            vec![mk_task(0, 0, 2, 0.1), mk_task(0, 2, 2, 0.2)],
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
            &WorkerFaults::none(),
        );
        assert_eq!((computed, skipped), (0, 2));
        assert!(events.iter().all(|e| e.outcome == Outcome::Cancelled));
        assert!(rx.recv().is_err(), "nothing should be emitted");
    }

    #[test]
    fn injected_crash_truncates_the_run() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false)]);
        let faults = WorkerFaults {
            crash_at: Some(1),
            ..WorkerFaults::none()
        };
        let (computed, skipped, events, crashed) = run_worker(
            0,
            vec![mk_task(0, 0, 2, 0.1), mk_task(0, 2, 2, 0.2), mk_task(0, 4, 2, 0.3)],
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
            &faults,
        );
        assert!(crashed);
        assert_eq!((computed, skipped), (1, 0));
        assert_eq!(events.len(), 1);
        let rows: Vec<usize> = rx.iter().map(|r| r.coded_start).collect();
        assert_eq!(rows, vec![0], "only the pre-crash sub-task published");
    }

    #[test]
    fn gray_failure_parks_until_cancelled() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false)]);
        let flag = Arc::clone(&cancel);
        // Cancel arrives "from the coordinator" while the worker parks.
        let canceller = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            flag[0].store(true, Ordering::SeqCst);
        });
        let faults = WorkerFaults {
            gray_from: Some(0),
            ..WorkerFaults::none()
        };
        let (computed, skipped, events, crashed) = run_worker(
            0,
            vec![mk_task(0, 0, 2, 0.1)],
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
            &faults,
        );
        canceller.join().unwrap();
        assert!(!crashed);
        assert_eq!((computed, skipped), (0, 1));
        assert_eq!(events[0].outcome, Outcome::Cancelled);
        assert!(rx.recv().is_err(), "gray compute publishes nothing");
    }

    #[test]
    fn flaky_fault_swaps_the_backend() {
        let (tx, rx) = channel();
        let cancel = Arc::new(vec![AtomicBool::new(false), AtomicBool::new(false)]);
        let faults = WorkerFaults {
            flaky_every: Some(2),
            ..WorkerFaults::none()
        };
        // Enough sub-tasks that the residue class ~1/2 hits some.
        let tasks: Vec<SubTask> = (0..8).map(|i| mk_task(i % 2, i * 2, 1, 0.1)).collect();
        let (computed, skipped, _, crashed) = run_worker(
            0,
            tasks,
            Backend::Native,
            cancel,
            tx,
            1e-6,
            Instant::now(),
            &faults,
        );
        assert!(!crashed);
        assert_eq!(computed + skipped, 8);
        assert!(skipped > 0, "flaky backend must fail some sub-tasks");
        drop(rx);
    }
}
