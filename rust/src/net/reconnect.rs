//! Reconnection policy: transient-vs-fatal error classification and a
//! capped exponential backoff schedule with deterministic jitter.
//!
//! The schedule is a pure function of `(policy, attempt)` — the jitter
//! comes from the policy's seeded [`crate::util::Rng`], never from
//! `SystemTime`, so a given (seed, attempt) pair always yields the same
//! delay and the property tests below can pin the schedule exactly.
//! Wall clocks enter only at the `thread::sleep` in
//! [`connect_with_retry`], outside the decision path.
//!
//! Classification answers one question: is this error the kind a
//! healthy-but-slow peer produces (refused while the listener is still
//! binding, reset by a restarting process, a timeout under load) or the
//! kind no amount of retrying fixes (address parse failure, permission
//! denied)? Transient errors buy a backoff slot; fatal ones surface
//! immediately.

use std::io;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::health::HealthConfig;
use crate::util::Rng;

/// Whether an I/O failure is worth retrying.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorClass {
    /// Peer-side or load-induced: refused / reset / aborted / timed
    /// out / interrupted. Retry with backoff.
    Transient,
    /// Configuration or environment: never self-heals. Fail now.
    Fatal,
}

/// Classify an I/O error for retry purposes.
pub fn classify(err: &io::Error) -> ErrorClass {
    use io::ErrorKind::*;
    match err.kind() {
        ConnectionRefused | ConnectionReset | ConnectionAborted | TimedOut | Interrupted
        | WouldBlock | BrokenPipe | UnexpectedEof | NotConnected | AddrInUse => {
            ErrorClass::Transient
        }
        _ => ErrorClass::Fatal,
    }
}

/// Capped exponential backoff with deterministic, seed-derived jitter.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// First retry delay (wall ms); attempt `a` waits ~`base · 2^a`.
    pub base_ms: f64,
    /// Pre-jitter ceiling on any single delay (wall ms).
    pub cap_ms: f64,
    /// Retries after the initial attempt; `0` disables retrying.
    pub max_attempts: u32,
    /// Jitter width as a fraction of the delay: the jittered delay is
    /// uniform in `d · [1 − j/2, 1 + j/2]`. Keeps a restarting fleet
    /// from reconnecting in lockstep while staying fully deterministic
    /// for a fixed seed.
    pub jitter_frac: f64,
    /// Jitter stream seed; mix in a session id so concurrent sessions
    /// de-synchronize.
    pub seed: u64,
}

impl RetryPolicy {
    /// Derive the policy from the run's health knobs: reconnect base /
    /// attempt budget come from the tracker config, the cap is shared
    /// with the breaker (one notion of "worst-case wait" per run).
    pub fn from_health(health: &HealthConfig, session: u64) -> Self {
        Self {
            base_ms: health.reconnect_base_ms.max(1.0),
            cap_ms: health.breaker_backoff_cap_ms.max(health.reconnect_base_ms),
            max_attempts: health.reconnect_attempts,
            jitter_frac: 0.25,
            seed: 0x5EED_0000_0000_0000 ^ session,
        }
    }

    /// The pre-jitter delay for retry `attempt` (0-based): monotone
    /// doubling from `base_ms`, saturating at `cap_ms`.
    pub fn raw_delay_ms(&self, attempt: u32) -> f64 {
        let exp = 2f64.powi(attempt.min(52) as i32);
        (self.base_ms * exp).min(self.cap_ms)
    }

    /// The jittered delay for retry `attempt`. Deterministic: the
    /// jitter draw comes from an RNG seeded by `(seed, attempt)` alone.
    pub fn delay_ms(&self, attempt: u32) -> f64 {
        let d = self.raw_delay_ms(attempt);
        let j = self.jitter_frac.clamp(0.0, 1.0);
        if j == 0.0 {
            return d;
        }
        let u = Rng::new(self.seed ^ (attempt as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15)).f64();
        d * (1.0 - j / 2.0 + j * u)
    }
}

static NEXT_SESSION: AtomicU64 = AtomicU64::new(1);

/// Allocate a process-unique, nonzero session id: the process id in the
/// high word, a monotone counter in the low. Session 0 is reserved on
/// the wire for "not resumable".
pub fn next_session_id() -> u64 {
    let n = NEXT_SESSION.fetch_add(1, Ordering::Relaxed);
    ((std::process::id() as u64) << 32) | (n & 0xffff_ffff)
}

/// Connect with the retry policy: the first attempt is immediate; each
/// transient failure schedules one backoff slot (reported through
/// `on_backoff(attempt, delay_ms)` before the sleep, so callers can log
/// a health event) up to `max_attempts` retries. Fatal errors and an
/// exhausted budget return the last error.
pub fn connect_with_retry(
    addr: &str,
    policy: &RetryPolicy,
    on_backoff: &mut dyn FnMut(u32, f64),
) -> io::Result<TcpStream> {
    let mut attempt = 0u32;
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if classify(&e) == ErrorClass::Fatal || attempt >= policy.max_attempts {
                    return Err(e);
                }
                let delay = policy.delay_ms(attempt);
                on_backoff(attempt, delay);
                std::thread::sleep(Duration::from_micros((delay * 1000.0) as u64));
                attempt += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, Config, Gen};

    fn random_policy(g: &mut Gen) -> RetryPolicy {
        RetryPolicy {
            base_ms: g.f64_range(1.0, 500.0),
            cap_ms: g.f64_range(500.0, 10_000.0),
            max_attempts: g.usize_range(0, 12) as u32,
            jitter_frac: g.f64_range(0.0, 1.0),
            seed: g.rng().next_u64(),
        }
    }

    #[test]
    fn prop_backoff_schedule_is_monotone_and_capped() {
        check(
            Config::default().cases(200),
            "raw schedule doubles monotonically up to the cap",
            |g| {
                let p = random_policy(g);
                let mut prev = 0.0;
                for a in 0..16u32 {
                    let d = p.raw_delay_ms(a);
                    assert!(d >= prev, "attempt {a}: {d} < previous {prev} ({p:?})");
                    assert!(d <= p.cap_ms, "attempt {a}: {d} above cap ({p:?})");
                    assert!(d > 0.0, "attempt {a}: non-positive delay ({p:?})");
                    prev = d;
                }
                // High attempts saturate exactly at the cap (base ≥ 1,
                // so 2^52 · base is astronomically past any cap here).
                assert_eq!(p.raw_delay_ms(60), p.cap_ms);
            },
        );
    }

    #[test]
    fn prop_jitter_is_bounded_and_deterministic() {
        check(
            Config::default().cases(200),
            "jittered delay ∈ d·[1−j/2, 1+j/2] and repeats per (seed, attempt)",
            |g| {
                let p = random_policy(g);
                for a in 0..12u32 {
                    let raw = p.raw_delay_ms(a);
                    let d = p.delay_ms(a);
                    let j = p.jitter_frac;
                    let (lo, hi) = (raw * (1.0 - j / 2.0), raw * (1.0 + j / 2.0));
                    assert!(
                        d >= lo - 1e-9 && d <= hi + 1e-9,
                        "attempt {a}: {d} outside [{lo}, {hi}] ({p:?})"
                    );
                    // Pure in (policy, attempt): same call, same answer.
                    assert_eq!(d.to_bits(), p.delay_ms(a).to_bits());
                }
                // A different seed perturbs at least one slot when the
                // jitter band is non-degenerate.
                if p.jitter_frac > 0.05 {
                    let q = RetryPolicy { seed: p.seed ^ 1, ..p };
                    assert!(
                        (0..12).any(|a| q.delay_ms(a).to_bits() != p.delay_ms(a).to_bits()),
                        "jitter ignored the seed entirely ({p:?})"
                    );
                }
            },
        );
    }

    #[test]
    fn classification_splits_transient_from_fatal() {
        use std::io::{Error, ErrorKind};
        for kind in [
            ErrorKind::ConnectionRefused,
            ErrorKind::ConnectionReset,
            ErrorKind::ConnectionAborted,
            ErrorKind::TimedOut,
            ErrorKind::Interrupted,
            ErrorKind::BrokenPipe,
            ErrorKind::UnexpectedEof,
        ] {
            assert_eq!(classify(&Error::from(kind)), ErrorClass::Transient, "{kind:?}");
        }
        for kind in [
            ErrorKind::PermissionDenied,
            ErrorKind::InvalidInput,
            ErrorKind::NotFound,
            ErrorKind::Unsupported,
        ] {
            assert_eq!(classify(&Error::from(kind)), ErrorClass::Fatal, "{kind:?}");
        }
    }

    #[test]
    fn session_ids_are_unique_and_nonzero() {
        let a = next_session_id();
        let b = next_session_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn connect_with_retry_gives_up_on_fatal_addresses() {
        // An unparseable address is fatal: no backoff slots burned.
        let policy = RetryPolicy {
            base_ms: 1.0,
            cap_ms: 2.0,
            max_attempts: 5,
            jitter_frac: 0.0,
            seed: 1,
        };
        let mut backoffs = 0;
        let err = connect_with_retry("not-an-address", &policy, &mut |_, _| backoffs += 1)
            .expect_err("must fail");
        assert_eq!(classify(&err), ErrorClass::Fatal);
        assert_eq!(backoffs, 0, "fatal errors must not consume retry slots");
    }

    #[test]
    fn connect_with_retry_exhausts_transient_budget() {
        // Bind-then-drop leaves a port that refuses connections:
        // transient, so every retry slot is consumed before giving up.
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let policy = RetryPolicy {
            base_ms: 0.1,
            cap_ms: 0.2,
            max_attempts: 3,
            jitter_frac: 0.0,
            seed: 1,
        };
        let mut slots = Vec::new();
        let err = connect_with_retry(&addr, &policy, &mut |a, d| slots.push((a, d)))
            .expect_err("nothing is listening");
        assert_eq!(classify(&err), ErrorClass::Transient);
        assert_eq!(slots.len(), 3, "all retry slots consumed: {slots:?}");
        assert_eq!(slots[0].0, 0);
        assert_eq!(slots[2].0, 2);
    }
}
