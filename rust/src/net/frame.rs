//! Length-prefixed framing over any byte stream.
//!
//! The wire unit is a *frame*: a little-endian `u32` payload length
//! followed by exactly that many payload bytes. Framing is transport-
//! agnostic — anything [`Read`]/[`Write`] works — so the codec tests run
//! against in-memory cursors while production runs over `std::net` TCP.
//! Payloads are capped at [`MAX_FRAME`] so a corrupt or hostile header
//! can never drive an unbounded allocation.
//!
//! Errors are typed: a clean close *between* frames is [`FrameError::Closed`]
//! (the conventional end-of-stream), a close *inside* a frame is
//! [`FrameError::Truncated`] (a protocol violation), and neither ever
//! panics.

use std::io::{self, Read, Write};

use super::messages::{CodecError, Message};

/// Hard cap on a frame payload (bytes). The largest legitimate frame is
/// a [`Message::TaskAssign`] carrying one coded row-block; 64 MiB leaves
/// ample headroom (a 4096×4096 f32 block is 64 MiB) while bounding what
/// a corrupt length header can make the receiver allocate.
pub const MAX_FRAME: usize = 64 << 20;

/// Framing failure (transport layer; message-level failures are
/// [`CodecError`]).
#[derive(Debug)]
pub enum FrameError {
    /// Peer closed the stream cleanly between frames (end of stream).
    Closed,
    /// Stream ended inside a header or payload: `got` of `expected`
    /// bytes arrived.
    Truncated { expected: usize, got: usize },
    /// Header announced a payload beyond [`MAX_FRAME`].
    Oversize { len: usize, max: usize },
    /// Underlying I/O failure.
    Io(io::Error),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Closed => write!(f, "stream closed"),
            FrameError::Truncated { expected, got } => {
                write!(f, "frame truncated: got {got} of {expected} bytes")
            }
            FrameError::Oversize { len, max } => {
                write!(f, "frame length {len} exceeds cap {max}")
            }
            FrameError::Io(e) => write!(f, "frame i/o: {e}"),
        }
    }
}

impl std::error::Error for FrameError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FrameError::Io(e) => Some(e),
            _ => None,
        }
    }
}

/// Receive failure: framing or message decode.
#[derive(Debug)]
pub enum WireError {
    Frame(FrameError),
    Codec(CodecError),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Frame(e) => write!(f, "{e}"),
            WireError::Codec(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<FrameError> for WireError {
    fn from(e: FrameError) -> Self {
        WireError::Frame(e)
    }
}

impl From<CodecError> for WireError {
    fn from(e: CodecError) -> Self {
        WireError::Codec(e)
    }
}

impl WireError {
    /// True when the peer closed cleanly between frames.
    pub fn is_closed(&self) -> bool {
        matches!(self, WireError::Frame(FrameError::Closed))
    }
}

/// Read until `buf` is full or EOF; returns bytes read. Interrupted
/// reads are retried (a worker loop must survive signal noise).
fn fill(r: &mut impl Read, buf: &mut [u8]) -> io::Result<usize> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(got)
}

/// Write one frame (length header + payload) and flush — flushing per
/// frame keeps control messages (Cancel, Heartbeat) low-latency behind
/// a `BufWriter`.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> io::Result<()> {
    assert!(
        payload.len() <= MAX_FRAME,
        "frame payload {} exceeds MAX_FRAME {}",
        payload.len(),
        MAX_FRAME
    );
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Read one frame payload.
pub fn read_frame(r: &mut impl Read) -> Result<Vec<u8>, FrameError> {
    let mut hdr = [0u8; 4];
    let got = fill(r, &mut hdr).map_err(FrameError::Io)?;
    if got == 0 {
        return Err(FrameError::Closed);
    }
    if got < 4 {
        return Err(FrameError::Truncated { expected: 4, got });
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::Oversize {
            len,
            max: MAX_FRAME,
        });
    }
    let mut payload = vec![0u8; len];
    let got = fill(r, &mut payload).map_err(FrameError::Io)?;
    if got < len {
        return Err(FrameError::Truncated {
            expected: len,
            got,
        });
    }
    Ok(payload)
}

/// Encode + frame + flush one message.
pub fn send(w: &mut impl Write, msg: &Message) -> io::Result<()> {
    write_frame(w, &msg.encode())
}

/// Encode + frame + flush one message, splitting into
/// [`Message::TaskAssignChunk`] frames when the encoding exceeds
/// `budget` bytes. The message is encoded once; each chunk copies a
/// single ≤ budget window, so peak extra memory is one chunk — not a
/// second full copy of the block. Messages at or under budget go out as
/// one plain frame (the common case pays nothing).
pub fn send_chunked(w: &mut impl Write, msg: &Message, budget: usize) -> io::Result<()> {
    let bytes = msg.encode();
    let budget = budget.max(1);
    if bytes.len() <= budget {
        return write_frame(w, &bytes);
    }
    let of = bytes.len().div_ceil(budget) as u32;
    for (seq, window) in bytes.chunks(budget).enumerate() {
        let chunk = Message::TaskAssignChunk {
            seq: seq as u32,
            of,
            payload: window.to_vec(),
        };
        write_frame(w, &chunk.encode())?;
    }
    Ok(())
}

/// Read + decode one message.
pub fn recv(r: &mut impl Read) -> Result<Message, WireError> {
    Ok(Message::decode(&read_frame(r)?)?)
}

/// Read + decode one message, accepting the previous protocol revision
/// too (worker side of a rolling upgrade); returns the frame's version
/// byte alongside the message so replies can be rendered in kind.
pub fn recv_compat(r: &mut impl Read) -> Result<(Message, u8), WireError> {
    let payload = read_frame(r)?;
    let version = payload.first().copied().unwrap_or(0);
    Ok((Message::decode_compat(&payload)?, version))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_roundtrip() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[7u8; 1000]).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(read_frame(&mut c).unwrap(), b"hello");
        assert_eq!(read_frame(&mut c).unwrap(), b"");
        assert_eq!(read_frame(&mut c).unwrap(), vec![7u8; 1000]);
        assert!(matches!(read_frame(&mut c), Err(FrameError::Closed)));
    }

    #[test]
    fn truncated_header_and_payload_are_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abcdef").unwrap();
        // Cut inside the header.
        let mut c = Cursor::new(&buf[..2]);
        assert!(matches!(
            read_frame(&mut c),
            Err(FrameError::Truncated { expected: 4, got: 2 })
        ));
        // Cut inside the payload.
        let mut c = Cursor::new(&buf[..7]);
        assert!(matches!(
            read_frame(&mut c),
            Err(FrameError::Truncated { expected: 6, got: 3 })
        ));
    }

    #[test]
    fn oversize_header_rejected_without_allocation() {
        let hdr = (u32::MAX).to_le_bytes();
        let mut c = Cursor::new(hdr.to_vec());
        assert!(matches!(
            read_frame(&mut c),
            Err(FrameError::Oversize { .. })
        ));
    }

    #[test]
    fn send_chunked_splits_and_reassembles_bit_for_bit() {
        use super::super::messages::ChunkAssembler;
        // A block whose encoding far exceeds the 1 KiB budget.
        let m = Message::TaskAssign {
            task: 0,
            coded_start: 0,
            rows: 16,
            cols: 64,
            delay_ms: 1.5,
            a_block: (0..16 * 64).map(|i| i as f32 * 0.5).collect(),
            x: (0..64).map(|i| -(i as f32)).collect(),
        };
        let budget = 1024;
        let mut buf = Vec::new();
        send_chunked(&mut buf, &m, budget).unwrap();

        let mut c = Cursor::new(buf);
        let mut asm = ChunkAssembler::new();
        let mut reassembled = None;
        let mut n_chunks = 0;
        while reassembled.is_none() {
            match recv(&mut c).unwrap() {
                Message::TaskAssignChunk { seq, of, payload } => {
                    assert!(payload.len() <= budget);
                    n_chunks += 1;
                    reassembled = asm.push(seq, of, &payload).unwrap();
                }
                other => panic!("expected chunk, got {other:?}"),
            }
        }
        let bytes = reassembled.unwrap();
        assert_eq!(bytes, m.encode(), "reassembly must be bit-for-bit");
        assert_eq!(n_chunks, m.encode().len().div_ceil(budget));
        assert_eq!(Message::decode(&bytes).unwrap(), m);
        assert!(recv(&mut c).unwrap_err().is_closed());

        // A small message under budget goes out as one plain frame.
        let small = Message::Cancel { task: 1 };
        let mut buf = Vec::new();
        send_chunked(&mut buf, &small, budget).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(recv(&mut c).unwrap(), small);
    }

    #[test]
    fn message_send_recv_roundtrip() {
        let mut buf = Vec::new();
        let m = Message::Cancel { task: 42 };
        send(&mut buf, &m).unwrap();
        let mut c = Cursor::new(buf);
        assert_eq!(recv(&mut c).unwrap(), m);
        assert!(recv(&mut c).unwrap_err().is_closed());
    }
}
