//! Socket-mode execution: the coordinator's communication leg on a
//! real wire.
//!
//! The paper's central claim is that *communication* delay — not just
//! computation — decides which assignment wins. The in-process runtime
//! models that delay by sampling it; this subsystem additionally puts
//! the bytes on a transport with genuine variability: `std::net` TCP,
//! no external dependencies (same vendored spirit as `anyhow`).
//!
//! Layers, bottom up:
//!
//! - [`frame`] — length-prefixed framing over any `Read`/`Write`
//!   (u32 LE header, [`frame::MAX_FRAME`] cap, typed errors, no panics);
//! - [`messages`] — the one shared [`messages::Message`] enum
//!   (Hello / TaskAssign / PartialResult / Cancel / Heartbeat /
//!   Shutdown) with a version-tagged binary codec;
//! - [`reconnect`] — retry policy: transient-vs-fatal error
//!   classification and capped exponential backoff with deterministic,
//!   seeded jitter (no `SystemTime` in the decision path);
//! - [`worker`] — [`crate::coordinator::worker::run_worker`] behind a
//!   listener: [`worker::WorkerServer`] is the `coded-coop worker`
//!   process; resumable sessions park unacked results for replay;
//! - [`transport`] — the coordinator-side seam: [`Transport`] on
//!   `RunOptions`/`StreamOptions` selects in-process channels or TCP
//!   per run; both paths feed the same collectors, so results and
//!   cancellation semantics stay in lockstep (see `tests/net_socket.rs`
//!   for the parity pin).

pub mod frame;
pub mod messages;
pub mod reconnect;
pub mod transport;
pub mod worker;

pub use transport::{TcpOptions, Transport};
pub use worker::{WorkerConfig, WorkerServer};
