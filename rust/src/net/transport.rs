//! The transport seam: how the coordinator's sub-task queues reach
//! their workers.
//!
//! [`Transport::Thread`] is the legacy in-process runtime (one OS
//! thread per worker, an mpsc results bus). [`Transport::Tcp`] puts the
//! same queues on a real wire: one TCP connection per *logical* worker
//! (per non-empty queue), the framed [`super::messages::Message`]
//! protocol, cancellation as `Cancel` frames, and drain stats coming
//! back in the worker's closing `Shutdown`. Both transports feed the
//! same coordinator-side `TaskCollector`s, so completion/cancellation
//! semantics — and the decoded results — cannot drift between them
//! (pinned by the parity test in `tests/net_socket.rs`).
//!
//! Endpoints: explicit addresses are round-robined over the live
//! queues (a worker process serves each connection on its own thread,
//! so fewer processes than queues is fine); with no addresses the
//! coordinator auto-spawns one loopback `coded-coop worker --listen
//! 127.0.0.1:0 --once` process per queue and discovers the OS-assigned
//! ports from their `LISTENING <addr>` announcements (bounded wait —
//! a child that dies or hangs before announcing is a typed error
//! carrying its stderr tail, never a coordinator wedge).
//!
//! ## Multi-host hardening
//!
//! Every connect (initial, re-queue) goes through
//! [`super::reconnect::connect_with_retry`]: a refused connection while
//! a remote worker is still binding its listener is a backoff slot, not
//! a failed run. When [`TcpOptions::auth`] carries a shared token its
//! digest rides in every `Hello`/`Resume`; workers started with the
//! same token drop unauthenticated peers at the first frame. Coded
//! row-blocks whose encoding exceeds [`CHUNK_BUDGET`] stream as
//! `TaskAssignChunk` frames so no single frame approaches the 64 MiB
//! cap.
//!
//! ## Health & recovery (armed only)
//!
//! When a [`FaultPlan`] is present (or [`HealthConfig::armed`] is set)
//! the dispatcher additionally runs the `health` layer: workers beat at
//! `HealthConfig::beat_ms`, a [`HealthTracker`] scores each session, a
//! per-worker [`CircuitBreaker`] gates re-dispatch, and a session that
//! crashes (reader error / `disconnected` drain) or goes sick (missed
//! beats, deadline stall, latency-spike streak) has its still-pending
//! sub-tasks re-queued onto breaker-allowed surviving workers over
//! fresh connections. Armed explicit-address sessions are additionally
//! *resumable*: they carry a nonzero session id, and on a disconnect
//! the coordinator first walks the reconnect backoff schedule sending
//! `Resume{session_id, last_acked_row}` — a worker that parked the
//! dropped session's results replays them (minus the acked prefix)
//! instead of anyone recomputing; only a resume miss falls back to
//! re-queue, and only an empty candidate set falls back to redundancy.
//! Re-queued or replayed arrivals are deduplicated by
//! `(master, coded_start)` — the MDS decode must never see the same
//! coded row twice. With no fault plan and `armed` off, every piece of
//! this bookkeeping is skipped and the dispatch path is byte-for-byte
//! the pre-health one (beats are disabled via `Hello.beat_ms = 0`,
//! sessions are not resumable).

use std::collections::HashSet;
use std::io::{self, BufRead, BufReader, BufWriter, Read};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::frame;
use super::messages::{auth_digest, Message, AUTH_LEN, CHUNK_BUDGET, NO_AUTH};
use super::reconnect::{self, ErrorClass, RetryPolicy};
use super::worker::{event_from_wire, RESUME_PARKED, RESUME_RUNNING};
use crate::coordinator::worker::{SubTask, TaskEvent, WorkerResult};
use crate::coordinator::TaskCollector;
use crate::health::{
    BreakerState, CircuitBreaker, FaultPlan, HealthConfig, HealthEvent, HealthEventKind,
    HealthTracker,
};

/// How the coordinator reaches its workers — selected per run on
/// [`crate::coordinator::RunOptions`] / [`crate::coordinator::StreamOptions`].
#[derive(Clone, Debug, Default)]
pub enum Transport {
    /// In-process worker threads over mpsc channels (the default).
    #[default]
    Thread,
    /// Worker processes over `std::net` TCP with the framed codec.
    Tcp(TcpOptions),
}

impl Transport {
    /// TCP transport to explicit worker endpoints (empty = auto-spawn
    /// loopback worker processes), no shared-secret auth.
    pub fn tcp(addrs: Vec<String>) -> Self {
        Transport::Tcp(TcpOptions { addrs, auth: None })
    }
}

/// TCP transport configuration.
#[derive(Clone, Debug, Default)]
pub struct TcpOptions {
    /// Worker endpoints (`host:port`), round-robined over the live
    /// queues. Empty: auto-spawn one loopback worker process per queue.
    pub addrs: Vec<String>,
    /// Shared-secret token: its digest is carried in every `Hello` /
    /// `Resume`, and auto-spawned workers inherit it via the
    /// `CODED_COOP_AUTH` environment (never argv — `ps` must not leak
    /// it). `None` sends the all-zero "no auth" digest.
    pub auth: Option<String>,
}

/// Coordinator-side connection writer (cancel broadcast + final ack).
type ConnWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// Longest we wait for a spawned worker's `LISTENING <addr>` announce
/// before declaring it wedged.
const ANNOUNCE_WAIT: Duration = Duration::from_secs(10);

/// Stderr lines retained per spawned worker for error reports.
const STDERR_TAIL_LINES: usize = 40;

/// An auto-spawned loopback worker process; killed on drop unless the
/// run reaped it cleanly.
struct SpawnedWorker {
    child: Child,
    addr: String,
    reaped: bool,
    /// Rolling tail of the child's stderr (a forwarder thread also
    /// echoes every line to our own stderr as it arrives).
    stderr_tail: Arc<Mutex<Vec<String>>>,
}

/// Snapshot the stderr tail for an error message, after a short pause
/// so the forwarder thread can flush the child's last words.
fn drain_stderr_tail(tail: &Arc<Mutex<Vec<String>>>) -> String {
    std::thread::sleep(Duration::from_millis(50));
    let text = tail
        .lock()
        .map(|t| t.join("\n"))
        .unwrap_or_default();
    if text.is_empty() {
        "<no stderr output>".to_string()
    } else {
        text
    }
}

impl SpawnedWorker {
    fn wait(&mut self) -> anyhow::Result<()> {
        let status = self.child.wait()?;
        self.reaped = true;
        anyhow::ensure!(
            status.success(),
            "spawned worker at {} exited with {status}; stderr tail:\n{}",
            self.addr,
            drain_stderr_tail(&self.stderr_tail)
        );
        Ok(())
    }
}

impl Drop for SpawnedWorker {
    fn drop(&mut self) {
        if !self.reaped {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }
}

/// Spawn one loopback worker process (`--once`: it exits when its
/// connection closes) and discover its OS-assigned port. `fault`
/// forwards an injection plan as `--fault <plan>` (recovery respawns
/// pass `None` — a replacement worker must not inherit the fault that
/// killed its predecessor); `auth` forwards the shared token through
/// the environment.
///
/// The announce read is bounded: it happens on a helper thread with a
/// [`ANNOUNCE_WAIT`] timeout, so a child that dies (or hangs) before
/// printing `LISTENING <addr>` yields a typed error carrying its exit
/// status and stderr tail instead of blocking the coordinator forever.
fn spawn_loopback_worker(
    fault: Option<&FaultPlan>,
    auth: Option<&str>,
) -> anyhow::Result<SpawnedWorker> {
    // Tests and wrappers can point at a prebuilt CLI; by default the
    // worker is this very binary re-entered as `coded-coop worker`.
    let exe = match std::env::var_os("CODED_COOP_WORKER_BIN") {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe()?,
    };
    let mut cmd = Command::new(&exe);
    cmd.arg("worker")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--once")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped());
    if let Some(plan) = fault {
        cmd.arg("--fault").arg(plan.to_string());
    }
    if let Some(token) = auth {
        cmd.env("CODED_COOP_AUTH", token);
    }
    let mut child = cmd
        .spawn()
        .map_err(|e| anyhow::anyhow!("spawning worker process {exe:?}: {e}"))?;
    let pid = child.id();

    let stderr = child
        .stderr
        .take()
        .ok_or_else(|| anyhow::anyhow!("spawned worker has no stderr"))?;
    let stderr_tail: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    {
        let tail = Arc::clone(&stderr_tail);
        std::thread::Builder::new()
            .name(format!("worker-stderr-{pid}"))
            .spawn(move || {
                for line in BufReader::new(stderr).lines() {
                    let Ok(line) = line else { break };
                    eprintln!("worker[{pid}] {line}");
                    let mut t = tail.lock().expect("stderr tail lock poisoned");
                    if t.len() >= STDERR_TAIL_LINES {
                        t.remove(0);
                    }
                    t.push(line);
                }
            })?;
    }

    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| anyhow::anyhow!("spawned worker has no stdout"))?;
    let (announce_tx, announce_rx) = channel::<io::Result<String>>();
    std::thread::Builder::new()
        .name(format!("worker-announce-{pid}"))
        .spawn(move || {
            let mut line = String::new();
            let res = BufReader::new(stdout).read_line(&mut line).map(|_| line);
            let _ = announce_tx.send(res);
        })?;
    let announced = match announce_rx.recv_timeout(ANNOUNCE_WAIT) {
        Ok(Ok(line)) => line,
        Ok(Err(e)) => {
            let _ = child.kill();
            let _ = child.wait();
            anyhow::bail!(
                "reading announce from worker pid {pid}: {e}; stderr tail:\n{}",
                drain_stderr_tail(&stderr_tail)
            );
        }
        Err(_) => {
            let _ = child.kill();
            let _ = child.wait();
            anyhow::bail!(
                "worker pid {pid} printed no 'LISTENING <addr>' within {ANNOUNCE_WAIT:?}; \
                 stderr tail:\n{}",
                drain_stderr_tail(&stderr_tail)
            );
        }
    };
    if announced.is_empty() {
        // EOF before any line: the child closed stdout — almost always
        // because it died on startup (bad flag, bind failure, panic).
        let _ = child.kill();
        let status = child
            .wait()
            .map(|s| s.to_string())
            .unwrap_or_else(|e| e.to_string());
        anyhow::bail!(
            "worker pid {pid} died before announcing its port ({status}); stderr tail:\n{}",
            drain_stderr_tail(&stderr_tail)
        );
    }
    let addr = announced
        .trim()
        .strip_prefix("LISTENING ")
        .ok_or_else(|| {
            anyhow::anyhow!(
                "worker process announced {announced:?} instead of 'LISTENING <addr>' \
                 (is {exe:?} a coded-coop binary?)"
            )
        })?
        .to_string();
    Ok(SpawnedWorker {
        child,
        addr,
        reaped: false,
        stderr_tail,
    })
}

/// Everything the reader threads feed back to the dispatch loop: data
/// results, health beats, and session drains (clean or not).
enum Pulse {
    Result(usize, WorkerResult),
    Beat {
        sid: usize,
        rows_done: u64,
        queue_depth: u32,
        last_latency_ms: f64,
    },
    Drained {
        sid: usize,
        computed: usize,
        skipped: usize,
        events: Vec<TaskEvent>,
        /// True when the session ended without the worker's closing
        /// `Shutdown` (reader error — the worker vanished) or when the
        /// worker itself reported a forced drain.
        disconnected: bool,
    },
}

/// Reader half of one worker connection: forward `PartialResult`s and
/// `Heartbeat`s to the dispatch loop until the worker's closing
/// `Shutdown` delivers its drain stats. A vanished worker yields a
/// `disconnected` drain with zero stats — its undelivered rows behave
/// like stragglers that never return, which the MDS redundancy may
/// still absorb (or, armed, the health layer resumes or re-queues).
fn reader_loop<R: Read>(mut reader: R, tx: Sender<Pulse>, sid: usize, wid: usize, addr: String) {
    loop {
        match frame::recv(&mut reader) {
            Ok(Message::PartialResult {
                task,
                coded_start,
                rows,
                worker,
                delay_ms,
                values,
            }) => {
                let _ = tx.send(Pulse::Result(
                    sid,
                    WorkerResult {
                        master: task as usize,
                        coded_start: coded_start as usize,
                        rows: rows as usize,
                        values,
                        delay_ms,
                        worker: worker as usize,
                    },
                ));
            }
            Ok(Message::Heartbeat {
                rows_done,
                queue_depth,
                last_latency_ms,
                ..
            }) => {
                let _ = tx.send(Pulse::Beat {
                    sid,
                    rows_done,
                    queue_depth,
                    last_latency_ms,
                });
            }
            Ok(Message::Shutdown {
                computed,
                skipped,
                disconnected,
                events,
            }) => {
                let _ = tx.send(Pulse::Drained {
                    sid,
                    computed: computed as usize,
                    skipped: skipped as usize,
                    events: events.iter().map(event_from_wire).collect(),
                    disconnected,
                });
                return;
            }
            Ok(_) => {} // benign
            Err(e) => {
                eprintln!(
                    "coordinator: worker {wid} at {addr} dropped mid-run: {e} \
                     (its remaining rows are lost; resume, re-queue or redundancy may still decode)"
                );
                let _ = tx.send(Pulse::Drained {
                    sid,
                    computed: 0,
                    skipped: 0,
                    events: Vec::new(),
                    disconnected: true,
                });
                return;
            }
        }
    }
}

/// One live (or finished) worker connection.
struct Session {
    /// Logical worker queue id — stats and breaker attribution.
    wid: usize,
    addr: String,
    writer: ConnWriter,
    /// Armed only: sub-tasks assigned to this session whose results
    /// have not arrived yet (clones — the originals went over the
    /// wire). The re-queue source on failure.
    pending: Vec<SubTask>,
    open: bool,
    /// The coordinator decided this session is sick and sent it a
    /// mid-run `Shutdown`; don't route cancels/re-queues to it.
    sick: bool,
    /// Wire session id (`0` = not resumable). Nonzero only for armed
    /// explicit-address sessions — auto-spawned `--once` workers die
    /// with their connection, so there is nothing to resume.
    session: u64,
    /// Rows received from this session so far: the resume watermark.
    /// The wire is FIFO, so the received results are a prefix of what
    /// the worker published — `Resume{last_acked_row}` tells it how
    /// much of its parked replay to skip.
    acked_rows: u64,
}

fn clone_task(t: &SubTask) -> SubTask {
    SubTask {
        master: t.master,
        coded_start: t.coded_start,
        rows: t.rows,
        cols: t.cols,
        a_block: t.a_block.clone(),
        x: Arc::clone(&t.x),
        delay_ms: t.delay_ms,
    }
}

/// Per-run dispatch parameters shared by every connection attempt.
struct DispatchCtx<'a> {
    n_cancel_slots: usize,
    time_scale: f64,
    beat_ms: f64,
    /// Auth digest carried in every `Hello` / `Resume` ([`NO_AUTH`]
    /// when no token is configured).
    auth: [u8; AUTH_LEN],
    /// The raw token, forwarded to auto-spawned workers via env.
    auth_token: Option<&'a str>,
    auto_spawn: bool,
    armed: bool,
    health: &'a HealthConfig,
}

/// Open one worker connection: connect (through the retry policy — a
/// transient refusal while a remote listener is still binding buys a
/// backoff slot, reported via `on_backoff`), handshake, stream the
/// queue (chunking any block whose encoding exceeds [`CHUNK_BUDGET`]),
/// release the start barrier if `barrier` (initial sessions barrier
/// together after ALL connect; recovery sessions start immediately),
/// and spawn its reader thread.
#[allow(clippy::too_many_arguments)]
fn open_session(
    sessions: &mut Vec<Session>,
    joins: &mut Vec<std::thread::JoinHandle<()>>,
    tx: &Sender<Pulse>,
    wid: usize,
    addr: &str,
    tasks: Vec<SubTask>,
    session: u64,
    ctx: &DispatchCtx,
    track_pending: bool,
    barrier: bool,
    on_backoff: &mut dyn FnMut(u32, f64),
) -> anyhow::Result<usize> {
    let sid = sessions.len();
    // Mix the worker id into the jitter seed so same-policy sessions
    // (session 0 everywhere when disarmed) still de-synchronize.
    let policy = RetryPolicy::from_health(ctx.health, session.wrapping_add(wid as u64));
    let stream = reconnect::connect_with_retry(addr, &policy, on_backoff)
        .map_err(|e| anyhow::anyhow!("connecting worker {wid} at {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    frame::send(
        &mut writer,
        &Message::Hello {
            wid: wid as u32,
            n_tasks: tasks.len() as u32,
            n_cancel_slots: ctx.n_cancel_slots as u32,
            time_scale: ctx.time_scale,
            beat_ms: ctx.beat_ms,
            session,
            auth: ctx.auth,
        },
    )?;
    match frame::recv(&mut reader) {
        Ok(Message::Hello { .. }) => {}
        Ok(other) => anyhow::bail!("worker {wid} at {addr}: expected Hello ack, got {other:?}"),
        Err(e) => anyhow::bail!(
            "worker {wid} at {addr}: handshake failed: {e} \
             (a protocol version mismatch or auth rejection closes the connection)"
        ),
    }
    // Armed dispatch clones the queue (the re-queue source on failure);
    // disarmed it moves straight onto the wire — no extra allocation on
    // the no-fault path.
    let pending: Vec<SubTask> = if track_pending {
        tasks.iter().map(clone_task).collect()
    } else {
        Vec::new()
    };
    for t in tasks {
        frame::send_chunked(
            &mut writer,
            &Message::TaskAssign {
                task: t.master as u32,
                coded_start: t.coded_start as u32,
                rows: t.rows as u32,
                cols: t.cols as u32,
                delay_ms: t.delay_ms,
                a_block: t.a_block,
                x: t.x.as_ref().clone(),
            },
            CHUNK_BUDGET,
        )?;
    }
    if barrier {
        frame::send(&mut writer, &barrier_beat())?;
    }
    let tx = tx.clone();
    let addr_owned = addr.to_string();
    let reader_addr = addr_owned.clone();
    joins.push(
        std::thread::Builder::new()
            .name(format!("net-reader-{wid}-{sid}"))
            .spawn(move || reader_loop(reader, tx, sid, wid, reader_addr))?,
    );
    sessions.push(Session {
        wid,
        addr: addr_owned,
        writer: Arc::new(Mutex::new(writer)),
        pending,
        open: true,
        sick: false,
        session,
        acked_rows: 0,
    });
    Ok(sid)
}

fn barrier_beat() -> Message {
    Message::Heartbeat {
        nonce: 0,
        rows_done: 0,
        queue_depth: 0,
        last_latency_ms: 0.0,
    }
}

/// TCP counterpart of the thread dispatcher: connect, assign, release
/// the start barrier, collect results (cancelling over the wire the
/// moment a task completes), then gather drain stats and release every
/// worker. Same signature contract as the thread path — per-worker
/// computed/skipped counts, the merged event log and the wall time —
/// plus the health-event log (always empty when the health layer is
/// disarmed).
pub(crate) fn dispatch_tcp(
    queues: Vec<Vec<SubTask>>,
    collectors: &mut [TaskCollector],
    opts: &TcpOptions,
    time_scale: f64,
    fault: Option<&FaultPlan>,
    health: &HealthConfig,
) -> anyhow::Result<(
    Vec<usize>,
    Vec<usize>,
    Vec<TaskEvent>,
    f64,
    Vec<HealthEvent>,
)> {
    let n_queues = queues.len();
    let armed = health.active(fault.is_some());
    let beat_ms = if armed { health.beat_ms } else { 0.0 };
    let mut worker_computed = vec![0usize; n_queues];
    let mut worker_skipped = vec![0usize; n_queues];
    let mut events: Vec<TaskEvent> = Vec::new();
    let mut health_events: Vec<HealthEvent> = Vec::new();
    let live: Vec<(usize, Vec<SubTask>)> = queues
        .into_iter()
        .enumerate()
        .filter(|(_, tasks)| !tasks.is_empty())
        .collect();
    if live.is_empty() {
        return Ok((worker_computed, worker_skipped, events, 0.0, health_events));
    }

    // ---- endpoints ------------------------------------------------------
    let auto_spawn = opts.addrs.is_empty();
    let ctx = DispatchCtx {
        n_cancel_slots: collectors.len(),
        time_scale,
        beat_ms,
        auth: opts.auth.as_deref().map(auth_digest).unwrap_or(NO_AUTH),
        auth_token: opts.auth.as_deref(),
        auto_spawn,
        armed,
        health,
    };
    let mut spawned: Vec<SpawnedWorker> = Vec::new();
    let addrs: Vec<String> = if auto_spawn {
        for _ in 0..live.len() {
            spawned.push(spawn_loopback_worker(fault, ctx.auth_token)?);
        }
        spawned.iter().map(|w| w.addr.clone()).collect()
    } else {
        (0..live.len())
            .map(|i| opts.addrs[i % opts.addrs.len()].clone())
            .collect()
    };

    let t_start = Instant::now();

    // ---- connect + handshake + assignment -------------------------------
    let (res_tx, res_rx) = channel::<Pulse>();
    let mut sessions: Vec<Session> = Vec::with_capacity(live.len());
    let mut joins: Vec<std::thread::JoinHandle<()>> = Vec::with_capacity(live.len());
    for ((wid, tasks), addr) in live.into_iter().zip(&addrs) {
        // Auto-spawned `--once` workers die with their connection —
        // nothing to resume — so only explicit-address armed sessions
        // get a (nonzero) resumable session id.
        let session = if armed && !auto_spawn {
            reconnect::next_session_id()
        } else {
            0
        };
        open_session(
            &mut sessions,
            &mut joins,
            &res_tx,
            wid,
            addr,
            tasks,
            session,
            &ctx,
            armed,
            false,
            &mut |attempt, delay_ms| {
                if armed {
                    health_events.push(HealthEvent {
                        at_ms: t_start.elapsed().as_secs_f64() * 1e3,
                        worker: wid,
                        kind: HealthEventKind::Backoff { attempt, delay_ms },
                    });
                }
            },
        )?;
    }

    // ---- start barrier: every worker has its full queue — go ------------
    for s in &sessions {
        frame::send(
            &mut *s.writer.lock().expect("writer lock poisoned"),
            &barrier_beat(),
        )?;
    }

    // ---- collect --------------------------------------------------------
    let mut tracker = HealthTracker::new(health);
    let mut breakers: Vec<CircuitBreaker> = (0..n_queues)
        .map(|_| CircuitBreaker::new(health.breaker_backoff_ms, health.breaker_backoff_cap_ms))
        .collect();
    if armed {
        for sid in 0..sessions.len() {
            tracker.on_connect(sid, 0.0);
        }
    }
    // Coded rows already absorbed — a re-queued duplicate must never
    // reach the decoder (duplicate rows make the LU system singular).
    let mut seen: HashSet<(usize, usize)> = HashSet::new();
    let mut done: Vec<bool> = vec![false; collectors.len()];
    let mut open_count = sessions.len();
    let tick = if armed {
        Duration::from_secs_f64((health.beat_ms.max(1.0)) * 1e-3)
    } else {
        Duration::from_millis(500)
    };

    // Detection runs on its own schedule at the top of the loop — it
    // must NOT live in the recv-timeout arm, because steady heartbeats
    // keep the channel busy and would starve a timeout-driven check
    // exactly when a gray worker (beats alive, compute dead) needs it.
    let mut next_detect_ms = if armed { health.beat_ms } else { f64::INFINITY };
    while open_count > 0 {
        let now_ms = t_start.elapsed().as_secs_f64() * 1e3;
        if armed && now_ms >= next_detect_ms {
            next_detect_ms = now_ms + health.beat_ms.max(1.0);
            // Judge every open, not-yet-sick session.
            for sid in 0..sessions.len() {
                if !sessions[sid].open || sessions[sid].sick {
                    continue;
                }
                let earliest = sessions[sid]
                    .pending
                    .iter()
                    .map(|t| t.delay_ms * time_scale * 1e3)
                    .min_by(|a, b| a.total_cmp(b));
                let verdict = tracker.verdict(sid, now_ms, earliest);
                if !verdict.is_sick() {
                    continue;
                }
                let wid = sessions[sid].wid;
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Suspect {
                        why: format!("{verdict:?}"),
                    },
                });
                breakers[wid].on_failure(now_ms);
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Open {
                        backoff_ms: breakers[wid].backoff_ms(),
                    },
                });
                // Release the sick worker: a mid-run Shutdown makes it
                // cancel everything and drain, so its session ends
                // instead of hanging the run.
                sessions[sid].sick = true;
                let _ = frame::send(
                    &mut *sessions[sid].writer.lock().expect("writer lock poisoned"),
                    &Message::Shutdown {
                        computed: 0,
                        skipped: 0,
                        disconnected: false,
                        events: Vec::new(),
                    },
                );
                requeue(
                    sid,
                    now_ms,
                    &mut sessions,
                    &mut joins,
                    &res_tx,
                    &mut spawned,
                    &mut breakers,
                    &tracker,
                    &done,
                    &ctx,
                    &mut health_events,
                    &mut open_count,
                )?;
            }
        }
        match res_rx.recv_timeout(tick) {
            Ok(Pulse::Result(sid, r)) => {
                if armed {
                    // Advance the resume watermark for every row this
                    // session delivered, duplicates included — the
                    // watermark counts receipt, not decoder use.
                    sessions[sid].acked_rows += r.rows as u64;
                    if !seen.insert((r.master, r.coded_start)) {
                        continue; // duplicate from a re-queue/replay race
                    }
                    sessions[sid]
                        .pending
                        .retain(|t| !(t.master == r.master && t.coded_start == r.coded_start));
                    tracker.on_result(sid, now_ms, r.rows as u64);
                    let wid = sessions[sid].wid;
                    if breakers[wid].state() == BreakerState::HalfOpen {
                        breakers[wid].on_success();
                        health_events.push(HealthEvent {
                            at_ms: now_ms,
                            worker: wid,
                            kind: HealthEventKind::Closed,
                        });
                    }
                }
                let Some(c) = collectors.get_mut(r.master) else {
                    continue; // malformed task id from the wire: drop, don't panic
                };
                if c.absorb(&r) {
                    // This arrival completed the task: cancel its
                    // redundancy on every worker (frames are honored
                    // between sub-tasks).
                    if let Some(d) = done.get_mut(r.master) {
                        *d = true;
                    }
                    for s in &sessions {
                        let _ = frame::send(
                            &mut *s.writer.lock().expect("writer lock poisoned"),
                            &Message::Cancel {
                                task: r.master as u32,
                            },
                        );
                    }
                    if armed {
                        for s in sessions.iter_mut() {
                            s.pending.retain(|t| t.master != r.master);
                        }
                    }
                }
            }
            Ok(Pulse::Beat {
                sid,
                rows_done,
                queue_depth,
                last_latency_ms,
            }) => {
                if armed {
                    tracker.on_beat(sid, now_ms, rows_done, queue_depth, last_latency_ms);
                }
            }
            Ok(Pulse::Drained {
                sid,
                computed,
                skipped,
                events: ev,
                disconnected,
            }) => {
                if sessions[sid].open {
                    sessions[sid].open = false;
                    open_count -= 1;
                }
                let wid = sessions[sid].wid;
                worker_computed[wid] += computed;
                worker_skipped[wid] += skipped;
                events.extend(ev);
                if armed {
                    tracker.on_drain(sid);
                    if disconnected && !sessions[sid].pending.is_empty() {
                        health_events.push(HealthEvent {
                            at_ms: now_ms,
                            worker: wid,
                            kind: HealthEventKind::Disconnect,
                        });
                        breakers[wid].on_failure(now_ms);
                        health_events.push(HealthEvent {
                            at_ms: now_ms,
                            worker: wid,
                            kind: HealthEventKind::Open {
                                backoff_ms: breakers[wid].backoff_ms(),
                            },
                        });
                        // Resumable sessions first try to reattach: a
                        // worker that parked the dropped session's
                        // results replays them instead of the fleet
                        // recomputing. Only a miss falls back to
                        // re-queue.
                        let resumed = sessions[sid].session != 0
                            && try_resume(
                                sid,
                                &mut sessions,
                                &mut joins,
                                &res_tx,
                                &mut breakers,
                                &mut tracker,
                                &ctx,
                                &t_start,
                                &mut health_events,
                                &mut open_count,
                            )?;
                        if !resumed {
                            requeue(
                                sid,
                                now_ms,
                                &mut sessions,
                                &mut joins,
                                &res_tx,
                                &mut spawned,
                                &mut breakers,
                                &tracker,
                                &done,
                                &ctx,
                                &mut health_events,
                                &mut open_count,
                            )?;
                        }
                    }
                }
            }
            // Quiet period — nothing to do; the detection sweep at the
            // top of the loop already ran for this interval.
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }

    // ---- release + reap -------------------------------------------------
    for s in &sessions {
        let _ = frame::send(
            &mut *s.writer.lock().expect("writer lock poisoned"),
            &Message::Shutdown {
                computed: 0,
                skipped: 0,
                disconnected: false,
                events: Vec::new(),
            },
        );
    }
    drop(sessions); // close the sockets: --once workers exit now
    drop(res_tx);
    for j in joins {
        let _ = j.join();
    }
    for mut s in spawned {
        s.wait()?;
    }
    Ok((
        worker_computed,
        worker_skipped,
        events,
        t_start.elapsed().as_secs_f64() * 1e3,
        health_events,
    ))
}

/// One `Resume` probe: connect, ask, classify the worker's reply.
enum ResumeReply {
    /// The worker parked this session — the returned connection will
    /// replay its results (minus the acked prefix) and drain.
    Parked(BufReader<TcpStream>, BufWriter<TcpStream>),
    /// The worker is still computing the dropped session's queue; ask
    /// again after a backoff slot.
    Running,
    /// The worker has no memory of this session (restart, eviction,
    /// crash) — fall back to re-queue.
    Miss,
}

fn resume_once(
    addr: &str,
    session_id: u64,
    last_acked_row: u64,
    auth: [u8; AUTH_LEN],
) -> Result<ResumeReply, ErrorClass> {
    let stream = TcpStream::connect(addr).map_err(|e| reconnect::classify(&e))?;
    stream.set_nodelay(true).ok();
    let Ok(clone) = stream.try_clone() else {
        return Err(ErrorClass::Transient);
    };
    let mut reader = BufReader::new(clone);
    let mut writer = BufWriter::new(stream);
    if frame::send(
        &mut writer,
        &Message::Resume {
            session_id,
            last_acked_row,
            auth,
        },
    )
    .is_err()
    {
        return Err(ErrorClass::Transient);
    }
    match frame::recv(&mut reader) {
        Ok(Message::Hello { n_cancel_slots, .. }) => match n_cancel_slots {
            RESUME_PARKED => Ok(ResumeReply::Parked(reader, writer)),
            RESUME_RUNNING => Ok(ResumeReply::Running),
            _ => Ok(ResumeReply::Miss),
        },
        Ok(_) => Ok(ResumeReply::Miss),
        // A closed stream here is a peer mid-restart (or an auth
        // rejection — which re-queue's fresh handshake will surface as
        // a hard error): retryable.
        Err(frame::WireError::Frame(_)) => Err(ErrorClass::Transient),
        // Codec garbage never self-heals.
        Err(frame::WireError::Codec(_)) => Err(ErrorClass::Fatal),
    }
}

/// Walk the reconnect backoff schedule trying to resume a disconnected
/// session in place: each probe either attaches a replay connection
/// (success — the dropped session's pending rows stay with it and the
/// worker's parked results flow in, deduplicated as usual), learns the
/// worker is still computing (wait a slot, ask again), or misses
/// (return `false` — the caller re-queues). Every slot is logged as a
/// `Backoff` health event; a successful attach logs `Reconnect` and
/// closes the worker's breaker.
#[allow(clippy::too_many_arguments)]
fn try_resume(
    sid: usize,
    sessions: &mut Vec<Session>,
    joins: &mut Vec<std::thread::JoinHandle<()>>,
    tx: &Sender<Pulse>,
    breakers: &mut [CircuitBreaker],
    tracker: &mut HealthTracker,
    ctx: &DispatchCtx,
    t_start: &Instant,
    health_events: &mut Vec<HealthEvent>,
    open_count: &mut usize,
) -> anyhow::Result<bool> {
    let session_id = sessions[sid].session;
    let wid = sessions[sid].wid;
    let addr = sessions[sid].addr.clone();
    let acked = sessions[sid].acked_rows;
    let policy = RetryPolicy::from_health(ctx.health, session_id);
    let mut attempt = 0u32;
    loop {
        match resume_once(&addr, session_id, acked, ctx.auth) {
            Ok(ResumeReply::Parked(reader, writer)) => {
                let now_ms = t_start.elapsed().as_secs_f64() * 1e3;
                let new_sid = sessions.len();
                let tx2 = tx.clone();
                let reader_addr = addr.clone();
                joins.push(
                    std::thread::Builder::new()
                        .name(format!("net-reader-{wid}-{new_sid}"))
                        .spawn(move || reader_loop(reader, tx2, new_sid, wid, reader_addr))?,
                );
                // The replay session inherits the dropped session's
                // pending rows and watermark — results retire them
                // exactly as if the original connection had lived.
                let pending = std::mem::take(&mut sessions[sid].pending);
                sessions.push(Session {
                    wid,
                    addr,
                    writer: Arc::new(Mutex::new(writer)),
                    pending,
                    open: true,
                    sick: false,
                    session: session_id,
                    acked_rows: acked,
                });
                *open_count += 1;
                tracker.on_connect(new_sid, now_ms);
                breakers[wid].on_success();
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Reconnect,
                });
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Closed,
                });
                return Ok(true);
            }
            Ok(ResumeReply::Miss) => return Ok(false),
            Ok(ResumeReply::Running) | Err(ErrorClass::Transient) => {
                if attempt >= policy.max_attempts {
                    return Ok(false);
                }
                let delay_ms = policy.delay_ms(attempt);
                health_events.push(HealthEvent {
                    at_ms: t_start.elapsed().as_secs_f64() * 1e3,
                    worker: wid,
                    kind: HealthEventKind::Backoff { attempt, delay_ms },
                });
                std::thread::sleep(Duration::from_micros((delay_ms * 1000.0) as u64));
                attempt += 1;
            }
            Err(ErrorClass::Fatal) => return Ok(false),
        }
    }
}

/// Move a failed session's still-pending sub-tasks onto the surviving
/// fleet: round-robin over breaker-allowed open sessions' worker ids
/// (least reported queue depth first), one fresh connection per target
/// — auto-spawn mode launches replacement processes WITHOUT the fault
/// plan, explicit-address mode reconnects to the target's endpoint.
/// Rows whose master already decoded are dropped, not re-sent. With no
/// allowed survivor the rows are abandoned to redundancy (exactly the
/// pre-health behavior).
#[allow(clippy::too_many_arguments)]
fn requeue(
    sid: usize,
    now_ms: f64,
    sessions: &mut Vec<Session>,
    joins: &mut Vec<std::thread::JoinHandle<()>>,
    tx: &Sender<Pulse>,
    spawned: &mut Vec<SpawnedWorker>,
    breakers: &mut [CircuitBreaker],
    tracker: &HealthTracker,
    done: &[bool],
    ctx: &DispatchCtx,
    health_events: &mut Vec<HealthEvent>,
    open_count: &mut usize,
) -> anyhow::Result<()> {
    let lost: Vec<SubTask> = std::mem::take(&mut sessions[sid].pending)
        .into_iter()
        .filter(|t| !done.get(t.master).copied().unwrap_or(false))
        .collect();
    if lost.is_empty() {
        return Ok(());
    }
    // Candidate targets: open healthy sessions, judged by their breaker
    // at `now_ms` (a previously tripped worker whose backoff elapsed
    // gets its half-open probe here), least-loaded first.
    let mut candidates: Vec<(u32, usize, String)> = Vec::new();
    let mut seen_wid: HashSet<usize> = HashSet::new();
    for (cand_sid, s) in sessions.iter().enumerate() {
        if !s.open || s.sick || !seen_wid.insert(s.wid) {
            continue;
        }
        if breakers[s.wid].allow(now_ms) {
            if breakers[s.wid].state() == BreakerState::HalfOpen {
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: s.wid,
                    kind: HealthEventKind::HalfOpen,
                });
            }
            candidates.push((tracker.queue_depth(cand_sid), s.wid, s.addr.clone()));
        }
    }
    candidates.sort();
    if candidates.is_empty() {
        eprintln!(
            "coordinator: no healthy worker to re-queue {} sub-tasks onto; \
             relying on redundancy",
            lost.len()
        );
        return Ok(());
    }
    // Round-robin the lost sub-tasks over the targets.
    let mut chunks: Vec<Vec<SubTask>> = (0..candidates.len()).map(|_| Vec::new()).collect();
    for (i, t) in lost.into_iter().enumerate() {
        chunks[i % candidates.len()].push(t);
    }
    for ((_, wid, addr), chunk) in candidates.into_iter().zip(chunks) {
        if chunk.is_empty() {
            continue;
        }
        let rows: usize = chunk.iter().map(|t| t.rows).sum();
        let endpoint = if ctx.auto_spawn {
            spawned.push(spawn_loopback_worker(None, ctx.auth_token)?);
            spawned.last().unwrap().addr.clone()
        } else {
            addr
        };
        let session = if ctx.armed && !ctx.auto_spawn {
            reconnect::next_session_id()
        } else {
            0
        };
        open_session(
            sessions,
            joins,
            tx,
            wid,
            &endpoint,
            chunk,
            session,
            ctx,
            true,
            true,
            &mut |attempt, delay_ms| {
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Backoff { attempt, delay_ms },
                });
            },
        )?;
        *open_count += 1;
        health_events.push(HealthEvent {
            at_ms: now_ms,
            worker: sessions[sid].wid,
            kind: HealthEventKind::Requeue { rows, to: wid },
        });
    }
    Ok(())
}
