//! The transport seam: how the coordinator's sub-task queues reach
//! their workers.
//!
//! [`Transport::Thread`] is the legacy in-process runtime (one OS
//! thread per worker, an mpsc results bus). [`Transport::Tcp`] puts the
//! same queues on a real wire: one TCP connection per *logical* worker
//! (per non-empty queue), the framed [`super::messages::Message`]
//! protocol, cancellation as `Cancel` frames, and drain stats coming
//! back in the worker's closing `Shutdown`. Both transports feed the
//! same coordinator-side `TaskCollector`s, so completion/cancellation
//! semantics — and the decoded results — cannot drift between them
//! (pinned by the parity test in `tests/net_socket.rs`).
//!
//! Endpoints: explicit addresses are round-robined over the live
//! queues (a worker process serves each connection on its own thread,
//! so fewer processes than queues is fine); with no addresses the
//! coordinator auto-spawns one loopback `coded-coop worker --listen
//! 127.0.0.1:0 --once` process per queue and discovers the OS-assigned
//! ports from their `LISTENING <addr>` announcements.

use std::io::{BufRead, BufReader, BufWriter, Read};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::frame;
use super::messages::Message;
use super::worker::event_from_wire;
use crate::coordinator::worker::{SubTask, TaskEvent, WorkerResult};
use crate::coordinator::TaskCollector;

/// How the coordinator reaches its workers — selected per run on
/// [`crate::coordinator::RunOptions`] / [`crate::coordinator::StreamOptions`].
#[derive(Clone, Debug, Default)]
pub enum Transport {
    /// In-process worker threads over mpsc channels (the default).
    #[default]
    Thread,
    /// Worker processes over `std::net` TCP with the framed codec.
    Tcp(TcpOptions),
}

impl Transport {
    /// TCP transport to explicit worker endpoints (empty = auto-spawn
    /// loopback worker processes).
    pub fn tcp(addrs: Vec<String>) -> Self {
        Transport::Tcp(TcpOptions {
            addrs,
            flaky: None,
        })
    }
}

/// TCP transport configuration.
#[derive(Clone, Debug, Default)]
pub struct TcpOptions {
    /// Worker endpoints (`host:port`), round-robined over the live
    /// queues. Empty: auto-spawn one loopback worker process per queue.
    pub addrs: Vec<String>,
    /// Fault injection forwarded to auto-spawned workers
    /// (`--flaky N`); rejected with explicit addresses — externally
    /// managed workers choose their own backend.
    pub flaky: Option<usize>,
}

/// Coordinator-side connection writer (cancel broadcast + final ack).
type ConnWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// An auto-spawned loopback worker process; killed on drop unless the
/// run reaped it cleanly.
struct SpawnedWorker {
    child: Child,
    addr: String,
    reaped: bool,
}

impl SpawnedWorker {
    fn wait(&mut self) -> anyhow::Result<()> {
        let status = self.child.wait()?;
        self.reaped = true;
        anyhow::ensure!(
            status.success(),
            "spawned worker at {} exited with {status}",
            self.addr
        );
        Ok(())
    }
}

impl Drop for SpawnedWorker {
    fn drop(&mut self) {
        if !self.reaped {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }
}

/// Spawn `n` loopback worker processes (`--once`: each exits when its
/// connection closes) and discover their OS-assigned ports.
fn spawn_loopback_workers(
    n: usize,
    flaky: Option<usize>,
) -> anyhow::Result<Vec<SpawnedWorker>> {
    // Tests and wrappers can point at a prebuilt CLI; by default the
    // worker is this very binary re-entered as `coded-coop worker`.
    let exe = match std::env::var_os("CODED_COOP_WORKER_BIN") {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe()?,
    };
    (0..n)
        .map(|_| {
            let mut cmd = Command::new(&exe);
            cmd.arg("worker")
                .arg("--listen")
                .arg("127.0.0.1:0")
                .arg("--once")
                .stdin(Stdio::null())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit());
            if let Some(every) = flaky {
                cmd.arg("--flaky").arg(every.to_string());
            }
            let mut child = cmd
                .spawn()
                .map_err(|e| anyhow::anyhow!("spawning worker process {exe:?}: {e}"))?;
            let stdout = child
                .stdout
                .take()
                .ok_or_else(|| anyhow::anyhow!("spawned worker has no stdout"))?;
            let mut line = String::new();
            BufReader::new(stdout).read_line(&mut line)?;
            let addr = line
                .trim()
                .strip_prefix("LISTENING ")
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "worker process announced {line:?} instead of 'LISTENING <addr>' \
                         (is {exe:?} a coded-coop binary?)"
                    )
                })?
                .to_string();
            Ok(SpawnedWorker {
                child,
                addr,
                reaped: false,
            })
        })
        .collect()
}

/// Reader half of one worker connection: forward `PartialResult`s to
/// the results bus until the worker's closing `Shutdown` delivers its
/// drain stats. A vanished worker yields zero stats — its undelivered
/// rows behave like stragglers that never return, which the MDS
/// redundancy may still absorb.
fn reader_loop<R: Read>(
    mut reader: R,
    tx: Sender<WorkerResult>,
    wid: usize,
    addr: String,
) -> (usize, usize, Vec<TaskEvent>) {
    loop {
        match frame::recv(&mut reader) {
            Ok(Message::PartialResult {
                task,
                coded_start,
                rows,
                worker,
                delay_ms,
                values,
            }) => {
                let _ = tx.send(WorkerResult {
                    master: task as usize,
                    coded_start: coded_start as usize,
                    rows: rows as usize,
                    values,
                    delay_ms,
                    worker: worker as usize,
                });
            }
            Ok(Message::Shutdown {
                computed,
                skipped,
                events,
            }) => {
                return (
                    computed as usize,
                    skipped as usize,
                    events.iter().map(event_from_wire).collect(),
                );
            }
            Ok(_) => {} // heartbeat echoes etc. — benign
            Err(e) => {
                eprintln!(
                    "coordinator: worker {wid} at {addr} dropped mid-run: {e} \
                     (its remaining rows are lost; redundancy may still decode)"
                );
                return (0, 0, Vec::new());
            }
        }
    }
}

/// TCP counterpart of the thread dispatcher: connect, assign, release
/// the start barrier, collect results (cancelling over the wire the
/// moment a task completes), then gather drain stats and release every
/// worker. Same signature contract as the thread path — per-worker
/// computed/skipped counts, the merged event log and the wall time.
pub(crate) fn dispatch_tcp(
    queues: Vec<Vec<SubTask>>,
    collectors: &mut [TaskCollector],
    opts: &TcpOptions,
    time_scale: f64,
) -> anyhow::Result<(Vec<usize>, Vec<usize>, Vec<TaskEvent>, f64)> {
    let n_queues = queues.len();
    let mut worker_computed = vec![0usize; n_queues];
    let mut worker_skipped = vec![0usize; n_queues];
    let mut events: Vec<TaskEvent> = Vec::new();
    let live: Vec<(usize, Vec<SubTask>)> = queues
        .into_iter()
        .enumerate()
        .filter(|(_, tasks)| !tasks.is_empty())
        .collect();
    if live.is_empty() {
        return Ok((worker_computed, worker_skipped, events, 0.0));
    }

    // ---- endpoints ------------------------------------------------------
    let mut spawned: Vec<SpawnedWorker> = Vec::new();
    let addrs: Vec<String> = if opts.addrs.is_empty() {
        spawned = spawn_loopback_workers(live.len(), opts.flaky)?;
        spawned.iter().map(|w| w.addr.clone()).collect()
    } else {
        anyhow::ensure!(
            opts.flaky.is_none(),
            "flaky injection configures auto-spawned workers; with explicit \
             addresses pass --flaky to the `coded-coop worker` processes instead"
        );
        (0..live.len())
            .map(|i| opts.addrs[i % opts.addrs.len()].clone())
            .collect()
    };

    let t_start = Instant::now();

    // ---- connect + handshake + assignment -------------------------------
    let mut writers: Vec<(usize, ConnWriter)> = Vec::with_capacity(live.len());
    let mut readers: Vec<(usize, String, BufReader<TcpStream>)> =
        Vec::with_capacity(live.len());
    for ((wid, tasks), addr) in live.into_iter().zip(&addrs) {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connecting worker {wid} at {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        frame::send(
            &mut writer,
            &Message::Hello {
                wid: wid as u32,
                n_tasks: tasks.len() as u32,
                n_cancel_slots: collectors.len() as u32,
                time_scale,
            },
        )?;
        match frame::recv(&mut reader) {
            Ok(Message::Hello { .. }) => {}
            Ok(other) => anyhow::bail!("worker {wid} at {addr}: expected Hello ack, got {other:?}"),
            Err(e) => anyhow::bail!(
                "worker {wid} at {addr}: handshake failed: {e} \
                 (protocol version mismatch closes the connection)"
            ),
        }
        for t in tasks {
            frame::send(
                &mut writer,
                &Message::TaskAssign {
                    task: t.master as u32,
                    coded_start: t.coded_start as u32,
                    rows: t.rows as u32,
                    cols: t.cols as u32,
                    delay_ms: t.delay_ms,
                    a_block: t.a_block,
                    x: t.x.as_ref().clone(),
                },
            )?;
        }
        writers.push((wid, Arc::new(Mutex::new(writer))));
        readers.push((wid, addr.clone(), reader));
    }

    // ---- start barrier: every worker has its full queue — go ------------
    for (_, w) in &writers {
        frame::send(
            &mut *w.lock().expect("writer lock poisoned"),
            &Message::Heartbeat { nonce: 0 },
        )?;
    }

    // ---- collect --------------------------------------------------------
    let (res_tx, res_rx) = channel::<WorkerResult>();
    let mut joins = Vec::with_capacity(readers.len());
    for (wid, addr, reader) in readers {
        let tx = res_tx.clone();
        joins.push((
            wid,
            std::thread::Builder::new()
                .name(format!("net-reader-{wid}"))
                .spawn(move || reader_loop(reader, tx, wid, addr))?,
        ));
    }
    drop(res_tx);
    while let Ok(r) = res_rx.recv() {
        let Some(c) = collectors.get_mut(r.master) else {
            continue; // malformed task id from the wire: drop, don't panic
        };
        if c.absorb(&r) {
            // This arrival completed the task: cancel its redundancy on
            // every worker (frames are honored between sub-tasks).
            for (_, w) in &writers {
                let _ = frame::send(
                    &mut *w.lock().expect("writer lock poisoned"),
                    &Message::Cancel {
                        task: r.master as u32,
                    },
                );
            }
        }
    }

    // ---- drain stats + release ------------------------------------------
    for (wid, h) in joins {
        let (computed, skipped, ev) = h
            .join()
            .map_err(|_| anyhow::anyhow!("reader thread for worker {wid} panicked"))?;
        worker_computed[wid] = computed;
        worker_skipped[wid] = skipped;
        events.extend(ev);
    }
    for (_, w) in &writers {
        let _ = frame::send(
            &mut *w.lock().expect("writer lock poisoned"),
            &Message::Shutdown {
                computed: 0,
                skipped: 0,
                events: Vec::new(),
            },
        );
    }
    drop(writers); // close the sockets: --once workers exit now
    for mut s in spawned {
        s.wait()?;
    }
    Ok((
        worker_computed,
        worker_skipped,
        events,
        t_start.elapsed().as_secs_f64() * 1e3,
    ))
}
