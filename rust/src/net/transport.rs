//! The transport seam: how the coordinator's sub-task queues reach
//! their workers.
//!
//! [`Transport::Thread`] is the legacy in-process runtime (one OS
//! thread per worker, an mpsc results bus). [`Transport::Tcp`] puts the
//! same queues on a real wire: one TCP connection per *logical* worker
//! (per non-empty queue), the framed [`super::messages::Message`]
//! protocol, cancellation as `Cancel` frames, and drain stats coming
//! back in the worker's closing `Shutdown`. Both transports feed the
//! same coordinator-side `TaskCollector`s, so completion/cancellation
//! semantics — and the decoded results — cannot drift between them
//! (pinned by the parity test in `tests/net_socket.rs`).
//!
//! Endpoints: explicit addresses are round-robined over the live
//! queues (a worker process serves each connection on its own thread,
//! so fewer processes than queues is fine); with no addresses the
//! coordinator auto-spawns one loopback `coded-coop worker --listen
//! 127.0.0.1:0 --once` process per queue and discovers the OS-assigned
//! ports from their `LISTENING <addr>` announcements.
//!
//! ## Health & recovery (armed only)
//!
//! When a [`FaultPlan`] is present (or [`HealthConfig::armed`] is set)
//! the dispatcher additionally runs the `health` layer: workers beat at
//! `HealthConfig::beat_ms`, a [`HealthTracker`] scores each session, a
//! per-worker [`CircuitBreaker`] gates re-dispatch, and a session that
//! crashes (reader error / `disconnected` drain) or goes sick (missed
//! beats, deadline stall, latency-spike streak) has its still-pending
//! sub-tasks re-queued onto breaker-allowed surviving workers over
//! fresh connections. Re-queued arrivals are deduplicated by
//! `(master, coded_start)` — the MDS decode must never see the same
//! coded row twice. With no fault plan and `armed` off, every piece of
//! this bookkeeping is skipped and the dispatch path is byte-for-byte
//! the pre-health one (beats are disabled via `Hello.beat_ms = 0`).

use std::collections::HashSet;
use std::io::{BufRead, BufReader, BufWriter, Read};
use std::net::TcpStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::frame;
use super::messages::Message;
use super::worker::event_from_wire;
use crate::coordinator::worker::{SubTask, TaskEvent, WorkerResult};
use crate::coordinator::TaskCollector;
use crate::health::{
    BreakerState, CircuitBreaker, FaultPlan, HealthConfig, HealthEvent, HealthEventKind,
    HealthTracker,
};

/// How the coordinator reaches its workers — selected per run on
/// [`crate::coordinator::RunOptions`] / [`crate::coordinator::StreamOptions`].
#[derive(Clone, Debug, Default)]
pub enum Transport {
    /// In-process worker threads over mpsc channels (the default).
    #[default]
    Thread,
    /// Worker processes over `std::net` TCP with the framed codec.
    Tcp(TcpOptions),
}

impl Transport {
    /// TCP transport to explicit worker endpoints (empty = auto-spawn
    /// loopback worker processes).
    pub fn tcp(addrs: Vec<String>) -> Self {
        Transport::Tcp(TcpOptions { addrs })
    }
}

/// TCP transport configuration.
#[derive(Clone, Debug, Default)]
pub struct TcpOptions {
    /// Worker endpoints (`host:port`), round-robined over the live
    /// queues. Empty: auto-spawn one loopback worker process per queue.
    pub addrs: Vec<String>,
}

/// Coordinator-side connection writer (cancel broadcast + final ack).
type ConnWriter = Arc<Mutex<BufWriter<TcpStream>>>;

/// An auto-spawned loopback worker process; killed on drop unless the
/// run reaped it cleanly.
struct SpawnedWorker {
    child: Child,
    addr: String,
    reaped: bool,
}

impl SpawnedWorker {
    fn wait(&mut self) -> anyhow::Result<()> {
        let status = self.child.wait()?;
        self.reaped = true;
        anyhow::ensure!(
            status.success(),
            "spawned worker at {} exited with {status}",
            self.addr
        );
        Ok(())
    }
}

impl Drop for SpawnedWorker {
    fn drop(&mut self) {
        if !self.reaped {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }
}

/// Spawn one loopback worker process (`--once`: it exits when its
/// connection closes) and discover its OS-assigned port. `fault`
/// forwards an injection plan as `--fault <plan>` (recovery respawns
/// pass `None` — a replacement worker must not inherit the fault that
/// killed its predecessor).
fn spawn_loopback_worker(fault: Option<&FaultPlan>) -> anyhow::Result<SpawnedWorker> {
    // Tests and wrappers can point at a prebuilt CLI; by default the
    // worker is this very binary re-entered as `coded-coop worker`.
    let exe = match std::env::var_os("CODED_COOP_WORKER_BIN") {
        Some(p) => PathBuf::from(p),
        None => std::env::current_exe()?,
    };
    let mut cmd = Command::new(&exe);
    cmd.arg("worker")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--once")
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if let Some(plan) = fault {
        cmd.arg("--fault").arg(plan.to_string());
    }
    let mut child = cmd
        .spawn()
        .map_err(|e| anyhow::anyhow!("spawning worker process {exe:?}: {e}"))?;
    let stdout = child
        .stdout
        .take()
        .ok_or_else(|| anyhow::anyhow!("spawned worker has no stdout"))?;
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line)?;
    let addr = line
        .trim()
        .strip_prefix("LISTENING ")
        .ok_or_else(|| {
            anyhow::anyhow!(
                "worker process announced {line:?} instead of 'LISTENING <addr>' \
                 (is {exe:?} a coded-coop binary?)"
            )
        })?
        .to_string();
    Ok(SpawnedWorker {
        child,
        addr,
        reaped: false,
    })
}

/// Everything the reader threads feed back to the dispatch loop: data
/// results, health beats, and session drains (clean or not).
enum Pulse {
    Result(usize, WorkerResult),
    Beat {
        sid: usize,
        rows_done: u64,
        queue_depth: u32,
        last_latency_ms: f64,
    },
    Drained {
        sid: usize,
        computed: usize,
        skipped: usize,
        events: Vec<TaskEvent>,
        /// True when the session ended without the worker's closing
        /// `Shutdown` (reader error — the worker vanished) or when the
        /// worker itself reported a forced drain.
        disconnected: bool,
    },
}

/// Reader half of one worker connection: forward `PartialResult`s and
/// `Heartbeat`s to the dispatch loop until the worker's closing
/// `Shutdown` delivers its drain stats. A vanished worker yields a
/// `disconnected` drain with zero stats — its undelivered rows behave
/// like stragglers that never return, which the MDS redundancy may
/// still absorb (or, armed, the health layer re-queues).
fn reader_loop<R: Read>(mut reader: R, tx: Sender<Pulse>, sid: usize, wid: usize, addr: String) {
    loop {
        match frame::recv(&mut reader) {
            Ok(Message::PartialResult {
                task,
                coded_start,
                rows,
                worker,
                delay_ms,
                values,
            }) => {
                let _ = tx.send(Pulse::Result(
                    sid,
                    WorkerResult {
                        master: task as usize,
                        coded_start: coded_start as usize,
                        rows: rows as usize,
                        values,
                        delay_ms,
                        worker: worker as usize,
                    },
                ));
            }
            Ok(Message::Heartbeat {
                rows_done,
                queue_depth,
                last_latency_ms,
                ..
            }) => {
                let _ = tx.send(Pulse::Beat {
                    sid,
                    rows_done,
                    queue_depth,
                    last_latency_ms,
                });
            }
            Ok(Message::Shutdown {
                computed,
                skipped,
                disconnected,
                events,
            }) => {
                let _ = tx.send(Pulse::Drained {
                    sid,
                    computed: computed as usize,
                    skipped: skipped as usize,
                    events: events.iter().map(event_from_wire).collect(),
                    disconnected,
                });
                return;
            }
            Ok(_) => {} // benign
            Err(e) => {
                eprintln!(
                    "coordinator: worker {wid} at {addr} dropped mid-run: {e} \
                     (its remaining rows are lost; redundancy or re-queue may still decode)"
                );
                let _ = tx.send(Pulse::Drained {
                    sid,
                    computed: 0,
                    skipped: 0,
                    events: Vec::new(),
                    disconnected: true,
                });
                return;
            }
        }
    }
}

/// One live (or finished) worker connection.
struct Session {
    /// Logical worker queue id — stats and breaker attribution.
    wid: usize,
    addr: String,
    writer: ConnWriter,
    /// Armed only: sub-tasks assigned to this session whose results
    /// have not arrived yet (clones — the originals went over the
    /// wire). The re-queue source on failure.
    pending: Vec<SubTask>,
    open: bool,
    /// The coordinator decided this session is sick and sent it a
    /// mid-run `Shutdown`; don't route cancels/re-queues to it.
    sick: bool,
}

fn clone_task(t: &SubTask) -> SubTask {
    SubTask {
        master: t.master,
        coded_start: t.coded_start,
        rows: t.rows,
        cols: t.cols,
        a_block: t.a_block.clone(),
        x: Arc::clone(&t.x),
        delay_ms: t.delay_ms,
    }
}

/// Open one worker connection: connect, handshake, stream the queue,
/// release the start barrier if `barrier` (initial sessions barrier
/// together after ALL connect; recovery sessions start immediately),
/// and spawn its reader thread.
#[allow(clippy::too_many_arguments)]
fn open_session(
    sessions: &mut Vec<Session>,
    joins: &mut Vec<std::thread::JoinHandle<()>>,
    tx: &Sender<Pulse>,
    wid: usize,
    addr: &str,
    tasks: Vec<SubTask>,
    n_cancel_slots: usize,
    time_scale: f64,
    beat_ms: f64,
    track_pending: bool,
    barrier: bool,
) -> anyhow::Result<usize> {
    let sid = sessions.len();
    let stream = TcpStream::connect(addr)
        .map_err(|e| anyhow::anyhow!("connecting worker {wid} at {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    frame::send(
        &mut writer,
        &Message::Hello {
            wid: wid as u32,
            n_tasks: tasks.len() as u32,
            n_cancel_slots: n_cancel_slots as u32,
            time_scale,
            beat_ms,
        },
    )?;
    match frame::recv(&mut reader) {
        Ok(Message::Hello { .. }) => {}
        Ok(other) => anyhow::bail!("worker {wid} at {addr}: expected Hello ack, got {other:?}"),
        Err(e) => anyhow::bail!(
            "worker {wid} at {addr}: handshake failed: {e} \
             (protocol version mismatch closes the connection)"
        ),
    }
    // Armed dispatch clones the queue (the re-queue source on failure);
    // disarmed it moves straight onto the wire — no extra allocation on
    // the no-fault path.
    let pending: Vec<SubTask> = if track_pending {
        tasks.iter().map(clone_task).collect()
    } else {
        Vec::new()
    };
    for t in tasks {
        frame::send(
            &mut writer,
            &Message::TaskAssign {
                task: t.master as u32,
                coded_start: t.coded_start as u32,
                rows: t.rows as u32,
                cols: t.cols as u32,
                delay_ms: t.delay_ms,
                a_block: t.a_block,
                x: t.x.as_ref().clone(),
            },
        )?;
    }
    if barrier {
        frame::send(&mut writer, &barrier_beat())?;
    }
    let tx = tx.clone();
    let addr_owned = addr.to_string();
    let reader_addr = addr_owned.clone();
    joins.push(
        std::thread::Builder::new()
            .name(format!("net-reader-{wid}-{sid}"))
            .spawn(move || reader_loop(reader, tx, sid, wid, reader_addr))?,
    );
    sessions.push(Session {
        wid,
        addr: addr_owned,
        writer: Arc::new(Mutex::new(writer)),
        pending,
        open: true,
        sick: false,
    });
    Ok(sid)
}

fn barrier_beat() -> Message {
    Message::Heartbeat {
        nonce: 0,
        rows_done: 0,
        queue_depth: 0,
        last_latency_ms: 0.0,
    }
}

/// TCP counterpart of the thread dispatcher: connect, assign, release
/// the start barrier, collect results (cancelling over the wire the
/// moment a task completes), then gather drain stats and release every
/// worker. Same signature contract as the thread path — per-worker
/// computed/skipped counts, the merged event log and the wall time —
/// plus the health-event log (always empty when the health layer is
/// disarmed).
pub(crate) fn dispatch_tcp(
    queues: Vec<Vec<SubTask>>,
    collectors: &mut [TaskCollector],
    opts: &TcpOptions,
    time_scale: f64,
    fault: Option<&FaultPlan>,
    health: &HealthConfig,
) -> anyhow::Result<(
    Vec<usize>,
    Vec<usize>,
    Vec<TaskEvent>,
    f64,
    Vec<HealthEvent>,
)> {
    let n_queues = queues.len();
    let armed = health.active(fault.is_some());
    let beat_ms = if armed { health.beat_ms } else { 0.0 };
    let mut worker_computed = vec![0usize; n_queues];
    let mut worker_skipped = vec![0usize; n_queues];
    let mut events: Vec<TaskEvent> = Vec::new();
    let mut health_events: Vec<HealthEvent> = Vec::new();
    let live: Vec<(usize, Vec<SubTask>)> = queues
        .into_iter()
        .enumerate()
        .filter(|(_, tasks)| !tasks.is_empty())
        .collect();
    if live.is_empty() {
        return Ok((worker_computed, worker_skipped, events, 0.0, health_events));
    }

    // ---- endpoints ------------------------------------------------------
    let mut spawned: Vec<SpawnedWorker> = Vec::new();
    let auto_spawn = opts.addrs.is_empty();
    let addrs: Vec<String> = if auto_spawn {
        for _ in 0..live.len() {
            spawned.push(spawn_loopback_worker(fault)?);
        }
        spawned.iter().map(|w| w.addr.clone()).collect()
    } else {
        (0..live.len())
            .map(|i| opts.addrs[i % opts.addrs.len()].clone())
            .collect()
    };

    let t_start = Instant::now();

    // ---- connect + handshake + assignment -------------------------------
    let (res_tx, res_rx) = channel::<Pulse>();
    let mut sessions: Vec<Session> = Vec::with_capacity(live.len());
    let mut joins: Vec<std::thread::JoinHandle<()>> = Vec::with_capacity(live.len());
    for ((wid, tasks), addr) in live.into_iter().zip(&addrs) {
        open_session(
            &mut sessions,
            &mut joins,
            &res_tx,
            wid,
            addr,
            tasks,
            collectors.len(),
            time_scale,
            beat_ms,
            armed,
            false,
        )?;
    }

    // ---- start barrier: every worker has its full queue — go ------------
    for s in &sessions {
        frame::send(
            &mut *s.writer.lock().expect("writer lock poisoned"),
            &barrier_beat(),
        )?;
    }

    // ---- collect --------------------------------------------------------
    let mut tracker = HealthTracker::new(health);
    let mut breakers: Vec<CircuitBreaker> = (0..n_queues)
        .map(|_| CircuitBreaker::new(health.breaker_backoff_ms, health.breaker_backoff_cap_ms))
        .collect();
    if armed {
        for sid in 0..sessions.len() {
            tracker.on_connect(sid, 0.0);
        }
    }
    // Coded rows already absorbed — a re-queued duplicate must never
    // reach the decoder (duplicate rows make the LU system singular).
    let mut seen: HashSet<(usize, usize)> = HashSet::new();
    let mut done: Vec<bool> = vec![false; collectors.len()];
    let mut open_count = sessions.len();
    let tick = if armed {
        Duration::from_secs_f64((health.beat_ms.max(1.0)) * 1e-3)
    } else {
        Duration::from_millis(500)
    };

    // Detection runs on its own schedule at the top of the loop — it
    // must NOT live in the recv-timeout arm, because steady heartbeats
    // keep the channel busy and would starve a timeout-driven check
    // exactly when a gray worker (beats alive, compute dead) needs it.
    let mut next_detect_ms = if armed { health.beat_ms } else { f64::INFINITY };
    while open_count > 0 {
        let now_ms = t_start.elapsed().as_secs_f64() * 1e3;
        if armed && now_ms >= next_detect_ms {
            next_detect_ms = now_ms + health.beat_ms.max(1.0);
            // Judge every open, not-yet-sick session.
            for sid in 0..sessions.len() {
                if !sessions[sid].open || sessions[sid].sick {
                    continue;
                }
                let earliest = sessions[sid]
                    .pending
                    .iter()
                    .map(|t| t.delay_ms * time_scale * 1e3)
                    .min_by(|a, b| a.total_cmp(b));
                let verdict = tracker.verdict(sid, now_ms, earliest);
                if !verdict.is_sick() {
                    continue;
                }
                let wid = sessions[sid].wid;
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Suspect {
                        why: format!("{verdict:?}"),
                    },
                });
                breakers[wid].on_failure(now_ms);
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: wid,
                    kind: HealthEventKind::Open {
                        backoff_ms: breakers[wid].backoff_ms(),
                    },
                });
                // Release the sick worker: a mid-run Shutdown makes it
                // cancel everything and drain, so its session ends
                // instead of hanging the run.
                sessions[sid].sick = true;
                let _ = frame::send(
                    &mut *sessions[sid].writer.lock().expect("writer lock poisoned"),
                    &Message::Shutdown {
                        computed: 0,
                        skipped: 0,
                        disconnected: false,
                        events: Vec::new(),
                    },
                );
                requeue(
                    sid,
                    now_ms,
                    &mut sessions,
                    &mut joins,
                    &res_tx,
                    &mut spawned,
                    auto_spawn,
                    &mut breakers,
                    &tracker,
                    &done,
                    collectors.len(),
                    time_scale,
                    beat_ms,
                    &mut health_events,
                    &mut open_count,
                )?;
            }
        }
        match res_rx.recv_timeout(tick) {
            Ok(Pulse::Result(sid, r)) => {
                if armed {
                    if !seen.insert((r.master, r.coded_start)) {
                        continue; // duplicate from a re-queue race
                    }
                    sessions[sid]
                        .pending
                        .retain(|t| !(t.master == r.master && t.coded_start == r.coded_start));
                    tracker.on_result(sid, now_ms, r.rows as u64);
                    let wid = sessions[sid].wid;
                    if breakers[wid].state() == BreakerState::HalfOpen {
                        breakers[wid].on_success();
                        health_events.push(HealthEvent {
                            at_ms: now_ms,
                            worker: wid,
                            kind: HealthEventKind::Closed,
                        });
                    }
                }
                let Some(c) = collectors.get_mut(r.master) else {
                    continue; // malformed task id from the wire: drop, don't panic
                };
                if c.absorb(&r) {
                    // This arrival completed the task: cancel its
                    // redundancy on every worker (frames are honored
                    // between sub-tasks).
                    if let Some(d) = done.get_mut(r.master) {
                        *d = true;
                    }
                    for s in &sessions {
                        let _ = frame::send(
                            &mut *s.writer.lock().expect("writer lock poisoned"),
                            &Message::Cancel {
                                task: r.master as u32,
                            },
                        );
                    }
                    if armed {
                        for s in sessions.iter_mut() {
                            s.pending.retain(|t| t.master != r.master);
                        }
                    }
                }
            }
            Ok(Pulse::Beat {
                sid,
                rows_done,
                queue_depth,
                last_latency_ms,
            }) => {
                if armed {
                    tracker.on_beat(sid, now_ms, rows_done, queue_depth, last_latency_ms);
                }
            }
            Ok(Pulse::Drained {
                sid,
                computed,
                skipped,
                events: ev,
                disconnected,
            }) => {
                if sessions[sid].open {
                    sessions[sid].open = false;
                    open_count -= 1;
                }
                let wid = sessions[sid].wid;
                worker_computed[wid] += computed;
                worker_skipped[wid] += skipped;
                events.extend(ev);
                if armed {
                    tracker.on_drain(sid);
                    if disconnected && !sessions[sid].pending.is_empty() {
                        health_events.push(HealthEvent {
                            at_ms: now_ms,
                            worker: wid,
                            kind: HealthEventKind::Disconnect,
                        });
                        breakers[wid].on_failure(now_ms);
                        health_events.push(HealthEvent {
                            at_ms: now_ms,
                            worker: wid,
                            kind: HealthEventKind::Open {
                                backoff_ms: breakers[wid].backoff_ms(),
                            },
                        });
                        requeue(
                            sid,
                            now_ms,
                            &mut sessions,
                            &mut joins,
                            &res_tx,
                            &mut spawned,
                            auto_spawn,
                            &mut breakers,
                            &tracker,
                            &done,
                            collectors.len(),
                            time_scale,
                            beat_ms,
                            &mut health_events,
                            &mut open_count,
                        )?;
                    }
                }
            }
            // Quiet period — nothing to do; the detection sweep at the
            // top of the loop already ran for this interval.
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }

    // ---- release + reap -------------------------------------------------
    for s in &sessions {
        let _ = frame::send(
            &mut *s.writer.lock().expect("writer lock poisoned"),
            &Message::Shutdown {
                computed: 0,
                skipped: 0,
                disconnected: false,
                events: Vec::new(),
            },
        );
    }
    drop(sessions); // close the sockets: --once workers exit now
    drop(res_tx);
    for j in joins {
        let _ = j.join();
    }
    for mut s in spawned {
        s.wait()?;
    }
    Ok((
        worker_computed,
        worker_skipped,
        events,
        t_start.elapsed().as_secs_f64() * 1e3,
        health_events,
    ))
}

/// Move a failed session's still-pending sub-tasks onto the surviving
/// fleet: round-robin over breaker-allowed open sessions' worker ids
/// (least reported queue depth first), one fresh connection per target
/// — auto-spawn mode launches replacement processes WITHOUT the fault
/// plan, explicit-address mode reconnects to the target's endpoint.
/// Rows whose master already decoded are dropped, not re-sent. With no
/// allowed survivor the rows are abandoned to redundancy (exactly the
/// pre-health behavior).
#[allow(clippy::too_many_arguments)]
fn requeue(
    sid: usize,
    now_ms: f64,
    sessions: &mut Vec<Session>,
    joins: &mut Vec<std::thread::JoinHandle<()>>,
    tx: &Sender<Pulse>,
    spawned: &mut Vec<SpawnedWorker>,
    auto_spawn: bool,
    breakers: &mut [CircuitBreaker],
    tracker: &HealthTracker,
    done: &[bool],
    n_cancel_slots: usize,
    time_scale: f64,
    beat_ms: f64,
    health_events: &mut Vec<HealthEvent>,
    open_count: &mut usize,
) -> anyhow::Result<()> {
    let lost: Vec<SubTask> = std::mem::take(&mut sessions[sid].pending)
        .into_iter()
        .filter(|t| !done.get(t.master).copied().unwrap_or(false))
        .collect();
    if lost.is_empty() {
        return Ok(());
    }
    // Candidate targets: open healthy sessions, judged by their breaker
    // at `now_ms` (a previously tripped worker whose backoff elapsed
    // gets its half-open probe here), least-loaded first.
    let mut candidates: Vec<(u32, usize, String)> = Vec::new();
    let mut seen_wid: HashSet<usize> = HashSet::new();
    for (cand_sid, s) in sessions.iter().enumerate() {
        if !s.open || s.sick || !seen_wid.insert(s.wid) {
            continue;
        }
        if breakers[s.wid].allow(now_ms) {
            if breakers[s.wid].state() == BreakerState::HalfOpen {
                health_events.push(HealthEvent {
                    at_ms: now_ms,
                    worker: s.wid,
                    kind: HealthEventKind::HalfOpen,
                });
            }
            candidates.push((tracker.queue_depth(cand_sid), s.wid, s.addr.clone()));
        }
    }
    candidates.sort();
    if candidates.is_empty() {
        eprintln!(
            "coordinator: no healthy worker to re-queue {} sub-tasks onto; \
             relying on redundancy",
            lost.len()
        );
        return Ok(());
    }
    // Round-robin the lost sub-tasks over the targets.
    let mut chunks: Vec<Vec<SubTask>> = (0..candidates.len()).map(|_| Vec::new()).collect();
    for (i, t) in lost.into_iter().enumerate() {
        chunks[i % candidates.len()].push(t);
    }
    for ((_, wid, addr), chunk) in candidates.into_iter().zip(chunks) {
        if chunk.is_empty() {
            continue;
        }
        let rows: usize = chunk.iter().map(|t| t.rows).sum();
        let endpoint = if auto_spawn {
            spawned.push(spawn_loopback_worker(None)?);
            spawned.last().unwrap().addr.clone()
        } else {
            addr
        };
        open_session(
            sessions, joins, tx, wid, &endpoint, chunk, n_cancel_slots, time_scale, beat_ms,
            true, true,
        )?;
        *open_count += 1;
        health_events.push(HealthEvent {
            at_ms: now_ms,
            worker: sessions[sid].wid,
            kind: HealthEventKind::Requeue { rows, to: wid },
        });
    }
    Ok(())
}
