//! The socket-mode worker: [`crate::coordinator::worker::run_worker`]
//! behind a TCP listener.
//!
//! A worker process serves connections; each connection is one *logical*
//! worker (one coordinator queue), so a single process can host many
//! logical workers when the coordinator round-robins its queues over
//! fewer addresses. Per connection the lifecycle is:
//!
//! 1. `Hello` handshake (version-checked by decode; the worker also
//!    accepts the previous protocol revision) announcing the logical
//!    worker id, task count, cancel-table size, time scale, session id
//!    and auth digest. The auth gate runs BEFORE any peer-sized
//!    allocation: a wrong token costs one constant-time compare and the
//!    connection is dropped without a reply. A connection may instead
//!    open with `Resume` to re-attach to a parked session (below).
//! 2. `n_tasks` × `TaskAssign`, buffered locally — each possibly
//!    streamed as `TaskAssignChunk` frames and reassembled here;
//! 3. one `Heartbeat` — the start barrier: the coordinator sends it
//!    only after EVERY worker has its full queue, so clocks start
//!    (nearly) together and wall-clock arrival order matches the
//!    thread-mode runtime;
//! 4. the unchanged [`run_worker`] loop executes on this thread while a
//!    control thread keeps reading the socket — `Cancel` flips the
//!    per-task flags mid-run, `Heartbeat` echoes, `Shutdown` cancels
//!    everything outstanding. On a NON-resumable session (`session ==
//!    0`) the peer vanishing also cancels everything, so the worker
//!    never computes for a dead coordinator; on a resumable session it
//!    keeps computing and parks results instead (below);
//! 5. a final `Shutdown` carries the drain stats + per-sub-task event
//!    log back, and the coordinator's closing `Shutdown` releases the
//!    connection.
//!
//! ## Resumable sessions
//!
//! A nonzero `Hello.session` registers the run in a process-global
//! parked-run registry. Every published `PartialResult` is also logged
//! there (results sitting in a dead socket's buffers are otherwise
//! unrecoverable), and a disconnect no longer cancels the queue — the
//! worker finishes and parks the drain stats. A later connection
//! opening with `Resume{session_id, last_acked_row}` gets a `Hello`
//! reply whose `n_cancel_slots` is a reply code ([`RESUME_MISS`] /
//! [`RESUME_PARKED`] / [`RESUME_RUNNING`]); on a hit the worker replays
//! the parked results past the coordinator's acked-row watermark — no
//! row is ever recomputed — and closes with the parked `Shutdown`
//! stats. The registry holds at most [`MAX_PARKED`] sessions (oldest
//! evicted) and an injected crash erases its entry, because a real
//! process death loses parked state too.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{Shutdown as SockShutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::frame;
use super::messages::{
    auth_digest, constant_time_eq, ChunkAssembler, CodecError, Message, WireEvent,
    LEGACY_VERSION, NO_AUTH,
};
use crate::coordinator::worker::{run_worker, SubTask, TaskEvent};
use crate::coordinator::Backend;
use crate::health::FaultPlan;

/// Configuration for a worker process / in-process worker server.
#[derive(Clone)]
pub struct WorkerConfig {
    /// Compute backend for sub-task mat-vecs (fault injection via
    /// [`Backend::flaky`] works over the wire exactly as in-process —
    /// the failing residue class hashes `(task, coded_start)`).
    pub backend: Backend,
    /// Serve exactly one connection, then return (used by auto-spawned
    /// loopback workers so the process exits with its run).
    pub once: bool,
    /// Injected faults, resolved per logical worker id at handshake
    /// time (`crash:w3@50%` only fires on the connection that Hello'd
    /// as wid 2).
    pub fault: Option<FaultPlan>,
    /// Shared-secret token; when set, every `Hello`/`Resume` must carry
    /// its digest or the connection is dropped before any allocation.
    pub auth: Option<String>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            backend: Backend::Native,
            once: false,
            fault: None,
            auth: None,
        }
    }
}

/// `Resume` reply codes, carried in the answering `Hello`'s
/// `n_cancel_slots` field.
///
/// Unknown session: the parked state is gone (evicted, crashed, or a
/// different process) — the coordinator falls back to re-queueing.
pub const RESUME_MISS: u32 = 0;
/// Hit: parked results + drain stats follow on this connection.
pub const RESUME_PARKED: u32 = 1;
/// The session is still computing; retry after a backoff slot.
pub const RESUME_RUNNING: u32 = 2;

/// Why the control loop exited (shared with the conn thread so the
/// closing drain stats can tell crash from completion).
const CTL_RUNNING: u8 = 0;
const CTL_RELEASED: u8 = 1; // coordinator sent Shutdown
const CTL_DISCONNECTED: u8 = 2; // peer vanished / stream error

/// Parked-run registry capacity; beyond it the oldest session is
/// evicted (its coordinator re-queues on resume miss, which is always
/// correct, just slower).
pub const MAX_PARKED: usize = 64;

/// State a resumable session leaves behind for a `Resume` replay.
struct ParkedRun {
    wid: usize,
    /// Still computing: a `Resume` now gets [`RESUME_RUNNING`].
    in_progress: bool,
    /// Every `PartialResult` published (or attempted) on the session,
    /// in publish order. Replay skips the coordinator's acked prefix.
    results: Vec<Message>,
    computed: u64,
    skipped: u64,
    events: Vec<WireEvent>,
}

fn registry() -> &'static Mutex<Vec<(u64, ParkedRun)>> {
    static REG: OnceLock<Mutex<Vec<(u64, ParkedRun)>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

fn registry_insert(session: u64, wid: usize) {
    let mut reg = registry().lock().expect("parked-run registry poisoned");
    reg.retain(|(id, _)| *id != session);
    if reg.len() >= MAX_PARKED {
        reg.remove(0);
    }
    reg.push((
        session,
        ParkedRun {
            wid,
            in_progress: true,
            results: Vec::new(),
            computed: 0,
            skipped: 0,
            events: Vec::new(),
        },
    ));
}

fn registry_park(session: u64, msg: Message) {
    let mut reg = registry().lock().expect("parked-run registry poisoned");
    if let Some((_, p)) = reg.iter_mut().find(|(id, _)| *id == session) {
        p.results.push(msg);
    }
}

fn registry_finish(session: u64, computed: u64, skipped: u64, events: Vec<WireEvent>) {
    let mut reg = registry().lock().expect("parked-run registry poisoned");
    if let Some((_, p)) = reg.iter_mut().find(|(id, _)| *id == session) {
        p.in_progress = false;
        p.computed = computed;
        p.skipped = skipped;
        p.events = events;
    }
}

fn registry_remove(session: u64) {
    let mut reg = registry().lock().expect("parked-run registry poisoned");
    reg.retain(|(id, _)| *id != session);
}

enum ResumeLookup {
    Miss,
    Running,
    Parked(ParkedRun),
}

fn registry_resume(session: u64) -> ResumeLookup {
    let mut reg = registry().lock().expect("parked-run registry poisoned");
    match reg.iter().position(|(id, _)| *id == session) {
        None => ResumeLookup::Miss,
        Some(i) if reg[i].1.in_progress => ResumeLookup::Running,
        Some(i) => ResumeLookup::Parked(reg.remove(i).1),
    }
}

/// Progress counters the beat thread samples, updated by the result
/// pump. `last_latency_bits` holds an `f64` (wall ms between
/// consecutive published results) as bits.
#[derive(Default)]
struct BeatState {
    rows_done: AtomicU64,
    tasks_done: AtomicU64,
    last_latency_bits: AtomicU64,
    stop: AtomicBool,
}

/// A bound worker listener. Binding is separated from serving so
/// callers (tests, the auto-spawner) can learn the OS-assigned port of
/// a `127.0.0.1:0` bind before the accept loop starts.
pub struct WorkerServer {
    listener: TcpListener,
}

impl WorkerServer {
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("worker: cannot listen on {addr}: {e}"))?;
        Ok(Self { listener })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop. Announces `LISTENING <addr>` on stdout first (the
    /// auto-spawner parses it for `:0` port discovery), then serves
    /// connections — sequentially with `once`, otherwise each on its
    /// own thread so one process can host several logical workers.
    pub fn run(self, cfg: &WorkerConfig) -> anyhow::Result<()> {
        let addr = self.local_addr()?;
        // println! would sit in the pipe buffer; the spawner reads this
        // line before connecting, so flush explicitly.
        {
            let mut out = io::stdout();
            writeln!(out, "LISTENING {addr}")?;
            out.flush()?;
        }
        if cfg.once {
            let (stream, _) = self.listener.accept()?;
            return handle_conn(stream, cfg.backend.clone(), cfg.fault.clone(), cfg.auth.clone());
        }
        loop {
            let (stream, peer) = self.listener.accept()?;
            let backend = cfg.backend.clone();
            let fault = cfg.fault.clone();
            let auth = cfg.auth.clone();
            std::thread::Builder::new()
                .name(format!("net-worker-{peer}"))
                .spawn(move || {
                    if let Err(e) = handle_conn(stream, backend, fault, auth) {
                        eprintln!("worker: connection {peer}: {e}");
                    }
                })?;
        }
    }
}

/// Writer half shared between the result pump and the control echo.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

fn send(w: &SharedWriter, msg: &Message) -> io::Result<()> {
    let mut g = w.lock().expect("writer lock poisoned");
    frame::send(&mut *g, msg)
}

/// Send rendering the frame in the peer's protocol revision.
fn send_as(w: &SharedWriter, msg: &Message, legacy: bool) -> io::Result<()> {
    let bytes = if legacy {
        msg.encode_legacy().ok_or_else(|| {
            io::Error::new(
                io::ErrorKind::InvalidInput,
                "message has no legacy encoding",
            )
        })?
    } else {
        msg.encode()
    };
    let mut g = w.lock().expect("writer lock poisoned");
    frame::write_frame(&mut *g, &bytes)
}

/// Validate + buffer one assignment (direct or reassembled from chunks).
#[allow(clippy::too_many_arguments)]
fn accept_assign(
    tasks: &mut Vec<SubTask>,
    n_tasks: usize,
    n_cancel_slots: usize,
    task: u32,
    coded_start: u32,
    rows: u32,
    cols: u32,
    delay_ms: f64,
    a_block: Vec<f32>,
    x: Vec<f32>,
) -> anyhow::Result<()> {
    let (rows, cols) = (rows as usize, cols as usize);
    anyhow::ensure!(
        a_block.len() == rows * cols && x.len() == cols,
        "TaskAssign shape mismatch: {}×{} block with {} + {} elements",
        rows,
        cols,
        a_block.len(),
        x.len(),
    );
    anyhow::ensure!(
        (task as usize) < n_cancel_slots,
        "TaskAssign task id {task} outside the {n_cancel_slots}-slot cancel table"
    );
    anyhow::ensure!(
        tasks.len() < n_tasks,
        "more TaskAssign frames than the announced {n_tasks}"
    );
    tasks.push(SubTask {
        master: task as usize,
        coded_start: coded_start as usize,
        rows,
        cols,
        a_block,
        x: Arc::new(x),
        delay_ms,
    });
    Ok(())
}

/// Serve one coordinator connection end-to-end (blocking).
pub fn handle_conn(
    stream: TcpStream,
    backend: Backend,
    fault: Option<FaultPlan>,
    auth: Option<String>,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream)));
    let required = auth.as_deref().map(auth_digest);

    // ---- 1. handshake: Hello or Resume ----------------------------------
    let (first, peer_version) = match frame::recv_compat(&mut reader) {
        Ok(p) => p,
        Err(e) => anyhow::bail!("handshake failed: {e}"),
    };
    let legacy = peer_version == LEGACY_VERSION;
    // The auth gate sits BEFORE any peer-sized allocation: a wrong or
    // missing token (a v2 peer has none) costs one constant-time
    // compare and the connection drops without revealing anything.
    if let Some(req) = &required {
        let presented = match &first {
            Message::Hello { auth, .. } | Message::Resume { auth, .. } => auth,
            other => anyhow::bail!("expected Hello or Resume, got {other:?}"),
        };
        if !constant_time_eq(req, presented) {
            if let Ok(g) = writer.lock() {
                let _ = g.get_ref().shutdown(SockShutdown::Both);
            }
            return Err(anyhow::Error::new(CodecError::AuthFailed));
        }
    }
    let (wid, n_tasks, n_cancel_slots, time_scale, beat_ms, session) = match first {
        Message::Hello {
            wid,
            n_tasks,
            n_cancel_slots,
            time_scale,
            beat_ms,
            session,
            ..
        } => (
            wid as usize,
            n_tasks as usize,
            n_cancel_slots as usize,
            time_scale,
            beat_ms,
            session,
        ),
        Message::Resume {
            session_id,
            last_acked_row,
            ..
        } => return serve_resume(reader, writer, session_id, last_acked_row),
        other => anyhow::bail!("expected Hello or Resume, got {other:?}"),
    };
    anyhow::ensure!(
        time_scale.is_finite() && time_scale >= 0.0,
        "Hello carried invalid time_scale {time_scale}"
    );
    anyhow::ensure!(
        beat_ms.is_finite(),
        "Hello carried invalid beat_ms {beat_ms}"
    );
    send_as(
        &writer,
        &Message::Hello {
            wid: wid as u32,
            n_tasks: 0,
            n_cancel_slots: 0,
            time_scale,
            beat_ms,
            session,
            auth: NO_AUTH,
        },
        legacy,
    )?;
    let mut faults = fault
        .as_ref()
        .map(|p| p.for_worker(wid, n_tasks))
        .unwrap_or_default();
    // A connection drop is injected here at the socket layer, not in
    // run_worker (which would treat it as a crash).
    let drop_at = faults.drop_at.take();
    // Resumable sessions only exist on the current protocol: a legacy
    // coordinator cannot send Resume, so a nonzero id from one (there
    // is no wire field; this is belt and braces) is ignored.
    let session = if legacy { 0 } else { session };

    // ---- 2./3. assignment + start barrier -------------------------------
    let cancel: Arc<Vec<AtomicBool>> =
        Arc::new((0..n_cancel_slots).map(|_| AtomicBool::new(false)).collect());
    let mut tasks: Vec<SubTask> = Vec::with_capacity(n_tasks);
    let mut asm = ChunkAssembler::new();
    loop {
        let (msg, _) = match frame::recv_compat(&mut reader) {
            Ok(p) => p,
            Err(e) => anyhow::bail!("assignment stream broke: {e}"),
        };
        anyhow::ensure!(
            !asm.in_progress() || matches!(msg, Message::TaskAssignChunk { .. }),
            "non-chunk frame interleaved mid-reassembly"
        );
        match msg {
            Message::TaskAssign {
                task,
                coded_start,
                rows,
                cols,
                delay_ms,
                a_block,
                x,
            } => accept_assign(
                &mut tasks,
                n_tasks,
                n_cancel_slots,
                task,
                coded_start,
                rows,
                cols,
                delay_ms,
                a_block,
                x,
            )?,
            Message::TaskAssignChunk { seq, of, payload } => {
                if let Some(bytes) = asm.push(seq, of, &payload)? {
                    // Chunks are a v3 construct; the inner message is
                    // strict current-version. No recursive chunking.
                    match Message::decode(&bytes)? {
                        Message::TaskAssign {
                            task,
                            coded_start,
                            rows,
                            cols,
                            delay_ms,
                            a_block,
                            x,
                        } => accept_assign(
                            &mut tasks,
                            n_tasks,
                            n_cancel_slots,
                            task,
                            coded_start,
                            rows,
                            cols,
                            delay_ms,
                            a_block,
                            x,
                        )?,
                        other => anyhow::bail!("chunked frame reassembled to {other:?}"),
                    }
                }
            }
            // The start barrier: first heartbeat after (or during — the
            // count guard above keeps phases honest) assignment.
            Message::Heartbeat { nonce, .. } => {
                if tasks.len() == n_tasks {
                    break;
                }
                send_as(
                    &writer,
                    &Message::Heartbeat {
                        nonce,
                        rows_done: 0,
                        queue_depth: 0,
                        last_latency_ms: 0.0,
                    },
                    legacy,
                )?;
            }
            Message::Cancel { task } => {
                if let Some(flag) = cancel.get(task as usize) {
                    flag.store(true, Ordering::SeqCst);
                }
            }
            // Drained before it started: ack and release.
            Message::Shutdown { .. } => {
                let _ = send_as(
                    &writer,
                    &Message::Shutdown {
                        computed: 0,
                        skipped: 0,
                        disconnected: false,
                        events: Vec::new(),
                    },
                    legacy,
                );
                return Ok(());
            }
            other => anyhow::bail!("unexpected {other:?} during assignment"),
        }
    }

    // ---- 4. execute: control + beat threads + the run_worker loop -------
    if session != 0 {
        registry_insert(session, wid);
    }
    let exit_cause = Arc::new(AtomicU8::new(CTL_RUNNING));
    let ctl = {
        let cancel = Arc::clone(&cancel);
        let writer = Arc::clone(&writer);
        let cause = Arc::clone(&exit_cause);
        let resumable = session != 0;
        std::thread::Builder::new()
            .name(format!("net-ctl-{wid}"))
            .spawn(move || control_loop(reader, writer, cancel, cause, resumable, legacy))?
    };

    let beat_state = Arc::new(BeatState::default());
    // Recurring progress beats at the coordinator-chosen cadence
    // (disabled for beat_ms ≤ 0). Nonces count up from 1; the barrier
    // heartbeat the coordinator sent used 0.
    let beat = if beat_ms > 0.0 {
        let writer = Arc::clone(&writer);
        let state = Arc::clone(&beat_state);
        let period = Duration::from_secs_f64(beat_ms * 1e-3);
        Some(
            std::thread::Builder::new()
                .name(format!("net-beat-{wid}"))
                .spawn(move || {
                    let mut nonce = 1u64;
                    while !state.stop.load(Ordering::SeqCst) {
                        std::thread::sleep(period);
                        if state.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let done = state.tasks_done.load(Ordering::SeqCst);
                        let msg = Message::Heartbeat {
                            nonce,
                            rows_done: state.rows_done.load(Ordering::SeqCst),
                            queue_depth: (n_tasks as u64).saturating_sub(done) as u32,
                            last_latency_ms: f64::from_bits(
                                state.last_latency_bits.load(Ordering::SeqCst),
                            ),
                        };
                        nonce += 1;
                        if send_as(&writer, &msg, legacy).is_err() {
                            return; // peer gone; the ctl thread handles it
                        }
                    }
                })?,
        )
    } else {
        None
    };

    let (tx, rx) = channel();
    let pump = {
        let writer = Arc::clone(&writer);
        let state = Arc::clone(&beat_state);
        std::thread::Builder::new()
            .name(format!("net-pump-{wid}"))
            .spawn(move || -> io::Result<()> {
                let mut last_publish: Option<Instant> = None;
                let mut published = 0usize;
                let mut socket_dead = false;
                for r in rx {
                    let now = Instant::now();
                    if let Some(prev) = last_publish {
                        let gap_ms = now.duration_since(prev).as_secs_f64() * 1e3;
                        state
                            .last_latency_bits
                            .store(gap_ms.to_bits(), Ordering::SeqCst);
                    }
                    last_publish = Some(now);
                    state.rows_done.fetch_add(r.rows as u64, Ordering::SeqCst);
                    state.tasks_done.fetch_add(1, Ordering::SeqCst);
                    let msg = Message::PartialResult {
                        task: r.master as u32,
                        coded_start: r.coded_start as u32,
                        rows: r.rows as u32,
                        worker: r.worker as u32,
                        delay_ms: r.delay_ms,
                        values: r.values,
                    };
                    // Park BEFORE the send: a result swallowed by a
                    // dying socket's buffers is still replayable, and
                    // the coordinator's (master, coded_start) dedup
                    // makes over-replay harmless.
                    if session != 0 {
                        registry_park(session, msg.clone());
                    }
                    // Injected connection drop: sever both ways at the
                    // trigger index and keep computing.
                    if !socket_dead && drop_at.is_some_and(|at| published >= at) {
                        if let Ok(g) = writer.lock() {
                            let _ = g.get_ref().shutdown(SockShutdown::Both);
                        }
                        socket_dead = true;
                    }
                    published += 1;
                    if !socket_dead {
                        if let Err(e) = send_as(&writer, &msg, legacy) {
                            if session == 0 {
                                return Err(e);
                            }
                            // Resumable: the queue keeps draining into
                            // the registry for a later Resume replay.
                            socket_dead = true;
                        }
                    }
                }
                Ok(())
            })?
    };

    let t_start = Instant::now();
    let (computed, skipped, events, crashed) =
        run_worker(wid, tasks, backend, cancel, tx, time_scale, t_start, &faults);

    // run_worker dropped its Sender, so the pump drains and exits.
    let pump_res = pump
        .join()
        .map_err(|_| anyhow::anyhow!("result pump panicked"))?;
    beat_state.stop.store(true, Ordering::SeqCst);

    if crashed {
        // Simulate the process dying: sever the socket both ways so the
        // coordinator's reader sees an immediate EOF (no closing
        // Shutdown, no drain stats), then exit CLEANLY — the injection
        // is the experiment, not a real defect, and the auto-spawner
        // treats a non-zero exit as a harness failure. A real death
        // loses parked state, so the injected one does too.
        if session != 0 {
            registry_remove(session);
        }
        if let Ok(g) = writer.lock() {
            let _ = g.get_ref().shutdown(SockShutdown::Both);
        }
        if let Some(b) = beat {
            let _ = b.join();
        }
        let _ = ctl.join();
        return Ok(());
    }
    pump_res.map_err(|e| anyhow::anyhow!("publishing results failed: {e}"))?;
    if let Some(b) = beat {
        b.join().map_err(|_| anyhow::anyhow!("beat thread panicked"))?;
    }

    // ---- 5. drain stats, then wait for the coordinator's release --------
    // `disconnected` marks a drain forced by the peer vanishing; a
    // coordinator-initiated Shutdown (or natural completion, where the
    // control loop is still running) is a clean drain.
    let wire_events: Vec<WireEvent> = events.iter().map(event_to_wire).collect();
    if session != 0 {
        // Park the drain stats FIRST: if the closing Shutdown never
        // reaches the peer, a Resume can still collect everything.
        registry_finish(session, computed as u64, skipped as u64, wire_events.clone());
    }
    let sent = send_as(
        &writer,
        &Message::Shutdown {
            computed: computed as u64,
            skipped: skipped as u64,
            disconnected: exit_cause.load(Ordering::SeqCst) == CTL_DISCONNECTED,
            events: wire_events,
        },
        legacy,
    );
    if session == 0 {
        sent?;
    }
    ctl.join()
        .map_err(|_| anyhow::anyhow!("control thread panicked"))?;
    if session != 0 && exit_cause.load(Ordering::SeqCst) == CTL_RELEASED {
        // Clean, coordinator-acknowledged release: nothing left to
        // resume. Any other exit keeps the parked entry alive.
        registry_remove(session);
    }
    Ok(())
}

/// Serve a `Resume` connection: reply code, then (on a hit) the parked
/// results past the acked watermark and the parked drain stats.
fn serve_resume<R: Read>(
    mut reader: R,
    writer: SharedWriter,
    session_id: u64,
    last_acked_row: u64,
) -> anyhow::Result<()> {
    let reply = |code: u32, wid: usize, n_results: usize| Message::Hello {
        wid: wid as u32,
        n_tasks: n_results as u32,
        n_cancel_slots: code,
        time_scale: 0.0,
        beat_ms: 0.0,
        session: session_id,
        auth: NO_AUTH,
    };
    match registry_resume(session_id) {
        ResumeLookup::Miss => {
            let _ = send(&writer, &reply(RESUME_MISS, 0, 0));
            Ok(())
        }
        ResumeLookup::Running => {
            let _ = send(&writer, &reply(RESUME_RUNNING, 0, 0));
            Ok(())
        }
        ResumeLookup::Parked(p) => {
            send(&writer, &reply(RESUME_PARKED, p.wid, p.results.len()))?;
            // Replay in publish order, skipping the prefix whose
            // cumulative rows the coordinator already absorbed. The
            // watermark is conservative (coordinator-side dedup makes
            // over-replay safe); what matters is never recomputing.
            let mut cum_rows = 0u64;
            for r in &p.results {
                if let Message::PartialResult { rows, .. } = r {
                    cum_rows += *rows as u64;
                    if cum_rows <= last_acked_row {
                        continue;
                    }
                }
                send(&writer, r)?;
            }
            send(
                &writer,
                &Message::Shutdown {
                    computed: p.computed,
                    skipped: p.skipped,
                    disconnected: false,
                    events: p.events.clone(),
                },
            )?;
            // Await the coordinator's release (or EOF) so our close
            // cannot race its reads of the replay.
            loop {
                match frame::recv(&mut reader) {
                    Ok(Message::Shutdown { .. }) | Err(_) => return Ok(()),
                    Ok(_) => {}
                }
            }
        }
    }
}

/// Keep reading control frames while (and after) the compute loop runs.
/// Returns when the coordinator releases the connection (`Shutdown`) or
/// vanishes, recording WHICH happened in `cause`. Both cancel
/// everything outstanding on a non-resumable session (a worker never
/// computes for a peer that stopped listening); a resumable session
/// keeps computing through a disconnect and parks its results instead.
fn control_loop<R: Read>(
    mut reader: R,
    writer: SharedWriter,
    cancel: Arc<Vec<AtomicBool>>,
    cause: Arc<AtomicU8>,
    resumable: bool,
    legacy: bool,
) {
    loop {
        match frame::recv_compat(&mut reader) {
            Ok((Message::Cancel { task }, _)) => {
                if let Some(flag) = cancel.get(task as usize) {
                    flag.store(true, Ordering::SeqCst);
                }
            }
            Ok((Message::Heartbeat { nonce, .. }, _)) => {
                let _ = send_as(
                    &writer,
                    &Message::Heartbeat {
                        nonce,
                        rows_done: 0,
                        queue_depth: 0,
                        last_latency_ms: 0.0,
                    },
                    legacy,
                );
            }
            Ok((Message::Shutdown { .. }, _)) => {
                cause.store(CTL_RELEASED, Ordering::SeqCst);
                for flag in cancel.iter() {
                    flag.store(true, Ordering::SeqCst);
                }
                return;
            }
            Err(_) => {
                cause.store(CTL_DISCONNECTED, Ordering::SeqCst);
                if !resumable {
                    for flag in cancel.iter() {
                        flag.store(true, Ordering::SeqCst);
                    }
                }
                return;
            }
            Ok(_) => {} // benign: ignore anything else mid-run
        }
    }
}

fn event_to_wire(e: &TaskEvent) -> WireEvent {
    WireEvent {
        worker: e.worker as u32,
        task: e.master as u32,
        rows: e.rows as u32,
        deadline_ms: e.deadline_ms,
        compute_wall_ms: e.compute_wall_ms,
        outcome: e.outcome,
    }
}

/// Wire event → the coordinator-side event record.
pub(crate) fn event_from_wire(e: &WireEvent) -> TaskEvent {
    TaskEvent {
        worker: e.worker as usize,
        master: e.task as usize,
        rows: e.rows as usize,
        deadline_ms: e.deadline_ms,
        compute_wall_ms: e.compute_wall_ms,
        outcome: e.outcome,
    }
}
