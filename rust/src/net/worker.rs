//! The socket-mode worker: [`crate::coordinator::worker::run_worker`]
//! behind a TCP listener.
//!
//! A worker process serves connections; each connection is one *logical*
//! worker (one coordinator queue), so a single process can host many
//! logical workers when the coordinator round-robins its queues over
//! fewer addresses. Per connection the lifecycle is:
//!
//! 1. `Hello` handshake (version-checked by decode) announcing the
//!    logical worker id, task count, cancel-table size and time scale;
//! 2. `n_tasks` × `TaskAssign`, buffered locally;
//! 3. one `Heartbeat` — the start barrier: the coordinator sends it
//!    only after EVERY worker has its full queue, so clocks start
//!    (nearly) together and wall-clock arrival order matches the
//!    thread-mode runtime;
//! 4. the unchanged [`run_worker`] loop executes on this thread while a
//!    control thread keeps reading the socket — `Cancel` flips the
//!    per-task flags mid-run, `Heartbeat` echoes, `Shutdown` (or the
//!    peer vanishing) cancels everything outstanding so the worker
//!    drains instead of computing for a dead coordinator;
//! 5. a final `Shutdown` carries the drain stats + per-sub-task event
//!    log back, and the coordinator's closing `Shutdown` releases the
//!    connection.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::net::{Shutdown as SockShutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::frame;
use super::messages::{Message, WireEvent};
use crate::coordinator::worker::{run_worker, SubTask, TaskEvent};
use crate::coordinator::Backend;
use crate::health::FaultPlan;

/// Configuration for a worker process / in-process worker server.
#[derive(Clone)]
pub struct WorkerConfig {
    /// Compute backend for sub-task mat-vecs (fault injection via
    /// [`Backend::flaky`] works over the wire exactly as in-process —
    /// the failing residue class hashes `(task, coded_start)`).
    pub backend: Backend,
    /// Serve exactly one connection, then return (used by auto-spawned
    /// loopback workers so the process exits with its run).
    pub once: bool,
    /// Injected faults, resolved per logical worker id at handshake
    /// time (`crash:w3@50%` only fires on the connection that Hello'd
    /// as wid 2).
    pub fault: Option<FaultPlan>,
}

impl Default for WorkerConfig {
    fn default() -> Self {
        Self {
            backend: Backend::Native,
            once: false,
            fault: None,
        }
    }
}

/// Why the control loop exited (shared with the conn thread so the
/// closing drain stats can tell crash from completion).
const CTL_RUNNING: u8 = 0;
const CTL_RELEASED: u8 = 1; // coordinator sent Shutdown
const CTL_DISCONNECTED: u8 = 2; // peer vanished / stream error

/// Progress counters the beat thread samples, updated by the result
/// pump. `last_latency_bits` holds an `f64` (wall ms between
/// consecutive published results) as bits.
#[derive(Default)]
struct BeatState {
    rows_done: AtomicU64,
    tasks_done: AtomicU64,
    last_latency_bits: AtomicU64,
    stop: AtomicBool,
}

/// A bound worker listener. Binding is separated from serving so
/// callers (tests, the auto-spawner) can learn the OS-assigned port of
/// a `127.0.0.1:0` bind before the accept loop starts.
pub struct WorkerServer {
    listener: TcpListener,
}

impl WorkerServer {
    pub fn bind(addr: &str) -> anyhow::Result<Self> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| anyhow::anyhow!("worker: cannot listen on {addr}: {e}"))?;
        Ok(Self { listener })
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Accept loop. Announces `LISTENING <addr>` on stdout first (the
    /// auto-spawner parses it for `:0` port discovery), then serves
    /// connections — sequentially with `once`, otherwise each on its
    /// own thread so one process can host several logical workers.
    pub fn run(self, cfg: &WorkerConfig) -> anyhow::Result<()> {
        let addr = self.local_addr()?;
        // println! would sit in the pipe buffer; the spawner reads this
        // line before connecting, so flush explicitly.
        {
            let mut out = io::stdout();
            writeln!(out, "LISTENING {addr}")?;
            out.flush()?;
        }
        if cfg.once {
            let (stream, _) = self.listener.accept()?;
            return handle_conn(stream, cfg.backend.clone(), cfg.fault.clone());
        }
        loop {
            let (stream, peer) = self.listener.accept()?;
            let backend = cfg.backend.clone();
            let fault = cfg.fault.clone();
            std::thread::Builder::new()
                .name(format!("net-worker-{peer}"))
                .spawn(move || {
                    if let Err(e) = handle_conn(stream, backend, fault) {
                        eprintln!("worker: connection {peer}: {e}");
                    }
                })?;
        }
    }
}

/// Writer half shared between the result pump and the control echo.
type SharedWriter = Arc<Mutex<BufWriter<TcpStream>>>;

fn send(w: &SharedWriter, msg: &Message) -> io::Result<()> {
    let mut g = w.lock().expect("writer lock poisoned");
    frame::send(&mut *g, msg)
}

/// Serve one coordinator connection end-to-end (blocking).
pub fn handle_conn(
    stream: TcpStream,
    backend: Backend,
    fault: Option<FaultPlan>,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let writer: SharedWriter = Arc::new(Mutex::new(BufWriter::new(stream)));

    // ---- 1. handshake ---------------------------------------------------
    let (wid, n_tasks, n_cancel_slots, time_scale, beat_ms) = match frame::recv(&mut reader)
    {
        Ok(Message::Hello {
            wid,
            n_tasks,
            n_cancel_slots,
            time_scale,
            beat_ms,
        }) => (
            wid as usize,
            n_tasks as usize,
            n_cancel_slots as usize,
            time_scale,
            beat_ms,
        ),
        Ok(other) => anyhow::bail!("expected Hello, got {other:?}"),
        Err(e) => anyhow::bail!("handshake failed: {e}"),
    };
    anyhow::ensure!(
        time_scale.is_finite() && time_scale >= 0.0,
        "Hello carried invalid time_scale {time_scale}"
    );
    anyhow::ensure!(
        beat_ms.is_finite(),
        "Hello carried invalid beat_ms {beat_ms}"
    );
    send(
        &writer,
        &Message::Hello {
            wid: wid as u32,
            n_tasks: 0,
            n_cancel_slots: 0,
            time_scale,
            beat_ms,
        },
    )?;
    let faults = fault
        .as_ref()
        .map(|p| p.for_worker(wid, n_tasks))
        .unwrap_or_default();

    // ---- 2./3. assignment + start barrier -------------------------------
    let cancel: Arc<Vec<AtomicBool>> =
        Arc::new((0..n_cancel_slots).map(|_| AtomicBool::new(false)).collect());
    let mut tasks: Vec<SubTask> = Vec::with_capacity(n_tasks);
    loop {
        match frame::recv(&mut reader) {
            Ok(Message::TaskAssign {
                task,
                coded_start,
                rows,
                cols,
                delay_ms,
                a_block,
                x,
            }) => {
                let (rows, cols) = (rows as usize, cols as usize);
                anyhow::ensure!(
                    a_block.len() == rows * cols && x.len() == cols,
                    "TaskAssign shape mismatch: {}×{} block with {} + {} elements",
                    rows,
                    cols,
                    a_block.len(),
                    x.len(),
                );
                anyhow::ensure!(
                    (task as usize) < n_cancel_slots,
                    "TaskAssign task id {task} outside the {n_cancel_slots}-slot cancel table"
                );
                anyhow::ensure!(
                    tasks.len() < n_tasks,
                    "more TaskAssign frames than the announced {n_tasks}"
                );
                tasks.push(SubTask {
                    master: task as usize,
                    coded_start: coded_start as usize,
                    rows,
                    cols,
                    a_block,
                    x: Arc::new(x),
                    delay_ms,
                });
            }
            // The start barrier: first heartbeat after (or during — the
            // count guard above keeps phases honest) assignment.
            Ok(Message::Heartbeat { nonce, .. }) => {
                if tasks.len() == n_tasks {
                    break;
                }
                send(
                    &writer,
                    &Message::Heartbeat {
                        nonce,
                        rows_done: 0,
                        queue_depth: 0,
                        last_latency_ms: 0.0,
                    },
                )?;
            }
            Ok(Message::Cancel { task }) => {
                if let Some(flag) = cancel.get(task as usize) {
                    flag.store(true, Ordering::SeqCst);
                }
            }
            // Drained before it started: ack and release.
            Ok(Message::Shutdown { .. }) => {
                let _ = send(
                    &writer,
                    &Message::Shutdown {
                        computed: 0,
                        skipped: 0,
                        disconnected: false,
                        events: Vec::new(),
                    },
                );
                return Ok(());
            }
            Ok(other) => anyhow::bail!("unexpected {other:?} during assignment"),
            Err(e) => anyhow::bail!("assignment stream broke: {e}"),
        }
    }

    // ---- 4. execute: control + beat threads + the run_worker loop -------
    let exit_cause = Arc::new(AtomicU8::new(CTL_RUNNING));
    let ctl = {
        let cancel = Arc::clone(&cancel);
        let writer = Arc::clone(&writer);
        let cause = Arc::clone(&exit_cause);
        std::thread::Builder::new()
            .name(format!("net-ctl-{wid}"))
            .spawn(move || control_loop(reader, writer, cancel, cause))?
    };

    let beat_state = Arc::new(BeatState::default());
    // Recurring progress beats at the coordinator-chosen cadence
    // (disabled for beat_ms ≤ 0). Nonces count up from 1; the barrier
    // heartbeat the coordinator sent used 0.
    let beat = if beat_ms > 0.0 {
        let writer = Arc::clone(&writer);
        let state = Arc::clone(&beat_state);
        let period = Duration::from_secs_f64(beat_ms * 1e-3);
        Some(
            std::thread::Builder::new()
                .name(format!("net-beat-{wid}"))
                .spawn(move || {
                    let mut nonce = 1u64;
                    while !state.stop.load(Ordering::SeqCst) {
                        std::thread::sleep(period);
                        if state.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let done = state.tasks_done.load(Ordering::SeqCst);
                        let msg = Message::Heartbeat {
                            nonce,
                            rows_done: state.rows_done.load(Ordering::SeqCst),
                            queue_depth: (n_tasks as u64).saturating_sub(done) as u32,
                            last_latency_ms: f64::from_bits(
                                state.last_latency_bits.load(Ordering::SeqCst),
                            ),
                        };
                        nonce += 1;
                        if send(&writer, &msg).is_err() {
                            return; // peer gone; the ctl thread handles it
                        }
                    }
                })?,
        )
    } else {
        None
    };

    let (tx, rx) = channel();
    let pump = {
        let writer = Arc::clone(&writer);
        let state = Arc::clone(&beat_state);
        std::thread::Builder::new()
            .name(format!("net-pump-{wid}"))
            .spawn(move || -> io::Result<()> {
                let mut last_publish: Option<Instant> = None;
                for r in rx {
                    let now = Instant::now();
                    if let Some(prev) = last_publish {
                        let gap_ms = now.duration_since(prev).as_secs_f64() * 1e3;
                        state
                            .last_latency_bits
                            .store(gap_ms.to_bits(), Ordering::SeqCst);
                    }
                    last_publish = Some(now);
                    state.rows_done.fetch_add(r.rows as u64, Ordering::SeqCst);
                    state.tasks_done.fetch_add(1, Ordering::SeqCst);
                    send(
                        &writer,
                        &Message::PartialResult {
                            task: r.master as u32,
                            coded_start: r.coded_start as u32,
                            rows: r.rows as u32,
                            worker: r.worker as u32,
                            delay_ms: r.delay_ms,
                            values: r.values,
                        },
                    )?;
                }
                Ok(())
            })?
    };

    let t_start = Instant::now();
    let (computed, skipped, events, crashed) =
        run_worker(wid, tasks, backend, cancel, tx, time_scale, t_start, &faults);

    // run_worker dropped its Sender, so the pump drains and exits.
    let pump_res = pump
        .join()
        .map_err(|_| anyhow::anyhow!("result pump panicked"))?;
    beat_state.stop.store(true, Ordering::SeqCst);

    if crashed {
        // Simulate the process dying: sever the socket both ways so the
        // coordinator's reader sees an immediate EOF (no closing
        // Shutdown, no drain stats), then exit CLEANLY — the injection
        // is the experiment, not a real defect, and the auto-spawner
        // treats a non-zero exit as a harness failure.
        if let Ok(g) = writer.lock() {
            let _ = g.get_ref().shutdown(SockShutdown::Both);
        }
        if let Some(b) = beat {
            let _ = b.join();
        }
        let _ = ctl.join();
        return Ok(());
    }
    pump_res.map_err(|e| anyhow::anyhow!("publishing results failed: {e}"))?;
    if let Some(b) = beat {
        b.join().map_err(|_| anyhow::anyhow!("beat thread panicked"))?;
    }

    // ---- 5. drain stats, then wait for the coordinator's release --------
    // `disconnected` marks a drain forced by the peer vanishing; a
    // coordinator-initiated Shutdown (or natural completion, where the
    // control loop is still running) is a clean drain.
    send(
        &writer,
        &Message::Shutdown {
            computed: computed as u64,
            skipped: skipped as u64,
            disconnected: exit_cause.load(Ordering::SeqCst) == CTL_DISCONNECTED,
            events: events.iter().map(event_to_wire).collect(),
        },
    )?;
    ctl.join()
        .map_err(|_| anyhow::anyhow!("control thread panicked"))?;
    Ok(())
}

/// Keep reading control frames while (and after) the compute loop runs.
/// Returns when the coordinator releases the connection (`Shutdown`) or
/// vanishes — both cancel everything outstanding, so a worker never
/// computes for a peer that stopped listening — and records WHICH of
/// the two happened in `cause` so the drain stats can report it.
fn control_loop<R: Read>(
    mut reader: R,
    writer: SharedWriter,
    cancel: Arc<Vec<AtomicBool>>,
    cause: Arc<AtomicU8>,
) {
    loop {
        match frame::recv(&mut reader) {
            Ok(Message::Cancel { task }) => {
                if let Some(flag) = cancel.get(task as usize) {
                    flag.store(true, Ordering::SeqCst);
                }
            }
            Ok(Message::Heartbeat { nonce, .. }) => {
                let _ = send(
                    &writer,
                    &Message::Heartbeat {
                        nonce,
                        rows_done: 0,
                        queue_depth: 0,
                        last_latency_ms: 0.0,
                    },
                );
            }
            Ok(Message::Shutdown { .. }) => {
                cause.store(CTL_RELEASED, Ordering::SeqCst);
                for flag in cancel.iter() {
                    flag.store(true, Ordering::SeqCst);
                }
                return;
            }
            Err(_) => {
                cause.store(CTL_DISCONNECTED, Ordering::SeqCst);
                for flag in cancel.iter() {
                    flag.store(true, Ordering::SeqCst);
                }
                return;
            }
            Ok(_) => {} // benign: ignore anything else mid-run
        }
    }
}

fn event_to_wire(e: &TaskEvent) -> WireEvent {
    WireEvent {
        worker: e.worker as u32,
        task: e.master as u32,
        rows: e.rows as u32,
        deadline_ms: e.deadline_ms,
        compute_wall_ms: e.compute_wall_ms,
        outcome: e.outcome,
    }
}

/// Wire event → the coordinator-side event record.
pub(crate) fn event_from_wire(e: &WireEvent) -> TaskEvent {
    TaskEvent {
        worker: e.worker as usize,
        master: e.task as usize,
        rows: e.rows as usize,
        deadline_ms: e.deadline_ms,
        compute_wall_ms: e.compute_wall_ms,
        outcome: e.outcome,
    }
}
