//! The shared wire vocabulary: one [`Message`] enum both sides of the
//! socket speak, with version-tagged binary encode/decode.
//!
//! Layout: every message body starts with `[version: u8][tag: u8]`,
//! then the variant's fields in declaration order — integers and IEEE
//! floats little-endian, vectors as a `u32` element count followed by
//! the elements. The version byte is checked on *every* decode, so a
//! coordinator and a worker from different protocol revisions fail the
//! handshake with a typed [`CodecError::BadVersion`] instead of
//! misparsing each other's frames.
//!
//! Decoding is total: any byte slice either decodes to exactly one
//! `Message` or returns a typed [`CodecError`] — truncation, unknown
//! tags, and corrupt length prefixes are errors, never panics, and a
//! length prefix is validated against the bytes actually present before
//! anything is allocated (fuzz-tested in `tests/net_socket.rs`).

use crate::coordinator::worker::Outcome;

/// Protocol revision; bumped on any wire-incompatible change.
/// v2: recurring progress heartbeats (`Heartbeat` carries rows done,
/// queue depth and last-task latency), a coordinator-chosen beat
/// cadence in `Hello`, and a `disconnected` flag in `Shutdown` drain
/// stats so crash and completion are distinguishable.
pub const PROTOCOL_VERSION: u8 = 2;

/// One worker-side task event as carried in [`Message::Shutdown`] — the
/// wire twin of [`crate::coordinator::worker::TaskEvent`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireEvent {
    pub worker: u32,
    /// Cancel-slot id (the coordinator's flat task id).
    pub task: u32,
    pub rows: u32,
    pub deadline_ms: f64,
    pub compute_wall_ms: f64,
    pub outcome: Outcome,
}

/// Everything that crosses the coordinator ↔ worker wire.
///
/// Lifecycle: coordinator connects and sends `Hello` (answered by a
/// `Hello` ack), then `n_tasks` × `TaskAssign`, then one `Heartbeat` as
/// the start barrier. The worker streams `PartialResult`s as deadlines
/// fire; the coordinator sends `Cancel` the moment a task decodes. When
/// the worker's queue drains it sends `Shutdown` carrying its drain
/// stats and event log, and the coordinator answers `Shutdown` to
/// release the connection.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Handshake (both directions). Coordinator → worker it announces
    /// the logical worker id, the task count to expect, the size of the
    /// cancellation table, the virtual-time scale and the heartbeat
    /// cadence it wants (`beat_ms ≤ 0` disables recurring beats);
    /// worker → coordinator it acknowledges (counts zeroed).
    Hello {
        wid: u32,
        n_tasks: u32,
        n_cancel_slots: u32,
        time_scale: f64,
        beat_ms: f64,
    },
    /// One coded row-block assignment (the wire twin of
    /// [`crate::coordinator::worker::SubTask`]).
    TaskAssign {
        /// Cancel-slot id (flat `(job, master)` id in stream mode).
        task: u32,
        coded_start: u32,
        rows: u32,
        cols: u32,
        /// Sampled virtual deadline (ms).
        delay_ms: f64,
        /// Row-major `rows × cols` coded block.
        a_block: Vec<f32>,
        /// Model vector (`cols`).
        x: Vec<f32>,
    },
    /// Computed products for one sub-task (worker → coordinator).
    PartialResult {
        task: u32,
        coded_start: u32,
        rows: u32,
        worker: u32,
        delay_ms: f64,
        values: Vec<f32>,
    },
    /// Stop work for one task (coordinator → worker): its master
    /// decoded. Honored between sub-tasks mid-run.
    Cancel { task: u32 },
    /// Liveness + progress beat. Coordinator → worker (fields zeroed)
    /// it is the post-assignment start barrier; worker → coordinator it
    /// is the recurring health beat carrying rows completed so far, the
    /// remaining queue depth and the worker's last observed per-task
    /// wall latency — the feed `health::HealthTracker` scores.
    Heartbeat {
        nonce: u64,
        rows_done: u64,
        queue_depth: u32,
        last_latency_ms: f64,
    },
    /// Graceful teardown. Worker → coordinator it carries the drain
    /// stats + event log, with `disconnected` marking a drain forced by
    /// an unexpected coordinator-side disconnect (vs. a clean
    /// coordinator-initiated `Shutdown` or natural queue completion);
    /// coordinator → worker (fields zeroed) it acknowledges and
    /// releases the connection. Received mid-run it cancels everything
    /// outstanding (drain).
    Shutdown {
        computed: u64,
        skipped: u64,
        disconnected: bool,
        events: Vec<WireEvent>,
    },
}

/// Message-level decode failure. Every variant is reachable from a
/// hostile or truncated byte slice; none of them panic.
#[derive(Clone, Debug, PartialEq)]
pub enum CodecError {
    /// Fewer bytes than the field at `offset` needs.
    Truncated {
        offset: usize,
        needed: usize,
        have: usize,
    },
    /// Version byte mismatch (incompatible peer).
    BadVersion { got: u8, want: u8 },
    /// Unknown message tag.
    BadTag(u8),
    /// Unknown outcome discriminant inside an event record.
    BadOutcome(u8),
    /// A boolean field byte other than 0 or 1 (a lucky garbage decode
    /// must still re-encode identically, so flags are strict).
    BadFlag(u8),
    /// A length prefix announced more elements than the remaining bytes
    /// can hold.
    Oversize { elems: usize, have: usize },
    /// Bytes left over after a complete message.
    Trailing { extra: usize },
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated {
                offset,
                needed,
                have,
            } => write!(
                f,
                "message truncated at byte {offset}: need {needed}, have {have}"
            ),
            CodecError::BadVersion { got, want } => {
                write!(f, "protocol version {got} (this build speaks {want})")
            }
            CodecError::BadTag(t) => write!(f, "unknown message tag {t}"),
            CodecError::BadOutcome(o) => write!(f, "unknown outcome discriminant {o}"),
            CodecError::BadFlag(b) => write!(f, "flag byte {b} is neither 0 nor 1"),
            CodecError::Oversize { elems, have } => {
                write!(f, "length prefix {elems} exceeds remaining {have} bytes")
            }
            CodecError::Trailing { extra } => {
                write!(f, "{extra} trailing bytes after message")
            }
        }
    }
}

impl std::error::Error for CodecError {}

const TAG_HELLO: u8 = 0;
const TAG_TASK_ASSIGN: u8 = 1;
const TAG_PARTIAL_RESULT: u8 = 2;
const TAG_CANCEL: u8 = 3;
const TAG_HEARTBEAT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;

/// Bytes per encoded [`WireEvent`]: worker + task + rows (u32) +
/// deadline + compute wall (f64) + outcome (u8).
const EVENT_BYTES: usize = 4 + 4 + 4 + 8 + 8 + 1;

fn outcome_to_u8(o: Outcome) -> u8 {
    match o {
        Outcome::Computed => 0,
        Outcome::Cancelled => 1,
        Outcome::Failed => 2,
    }
}

fn outcome_from_u8(b: u8) -> Result<Outcome, CodecError> {
    match b {
        0 => Ok(Outcome::Computed),
        1 => Ok(Outcome::Cancelled),
        2 => Ok(Outcome::Failed),
        other => Err(CodecError::BadOutcome(other)),
    }
}

// ---- encoding -----------------------------------------------------------

struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn events(&mut self, evs: &[WireEvent]) {
        self.u32(evs.len() as u32);
        for e in evs {
            self.u32(e.worker);
            self.u32(e.task);
            self.u32(e.rows);
            self.f64(e.deadline_ms);
            self.f64(e.compute_wall_ms);
            self.u8(outcome_to_u8(e.outcome));
        }
    }
}

// ---- decoding -----------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take<const N: usize>(&mut self) -> Result<[u8; N], CodecError> {
        if self.remaining() < N {
            return Err(CodecError::Truncated {
                offset: self.pos,
                needed: N,
                have: self.remaining(),
            });
        }
        let mut out = [0u8; N];
        out.copy_from_slice(&self.buf[self.pos..self.pos + N]);
        self.pos += N;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take::<1>()?[0])
    }
    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take::<4>()?))
    }
    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take::<8>()?))
    }
    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_le_bytes(self.take::<8>()?))
    }

    /// Strict boolean: any byte other than 0/1 is a typed error so
    /// decode(encode(m)) == m implies encode(decode(b)) == b.
    fn flag(&mut self) -> Result<bool, CodecError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(CodecError::BadFlag(other)),
        }
    }

    /// Length prefix validated against remaining bytes BEFORE the
    /// allocation, so a corrupt prefix cannot drive an OOM.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize, CodecError> {
        let n = self.u32()? as usize;
        match n.checked_mul(elem_bytes) {
            Some(need) if need <= self.remaining() => Ok(n),
            _ => Err(CodecError::Oversize {
                elems: n,
                have: self.remaining(),
            }),
        }
    }

    fn f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.len_prefix(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_le_bytes(self.take::<4>()?));
        }
        Ok(out)
    }

    fn events(&mut self) -> Result<Vec<WireEvent>, CodecError> {
        let n = self.len_prefix(EVENT_BYTES)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(WireEvent {
                worker: self.u32()?,
                task: self.u32()?,
                rows: self.u32()?,
                deadline_ms: self.f64()?,
                compute_wall_ms: self.f64()?,
                outcome: outcome_from_u8(self.u8()?)?,
            });
        }
        Ok(out)
    }

    fn finish(self) -> Result<(), CodecError> {
        if self.remaining() > 0 {
            return Err(CodecError::Trailing {
                extra: self.remaining(),
            });
        }
        Ok(())
    }
}

impl Message {
    /// Serialize to the version-tagged binary layout.
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc(Vec::with_capacity(16));
        e.u8(PROTOCOL_VERSION);
        match self {
            Message::Hello {
                wid,
                n_tasks,
                n_cancel_slots,
                time_scale,
                beat_ms,
            } => {
                e.u8(TAG_HELLO);
                e.u32(*wid);
                e.u32(*n_tasks);
                e.u32(*n_cancel_slots);
                e.f64(*time_scale);
                e.f64(*beat_ms);
            }
            Message::TaskAssign {
                task,
                coded_start,
                rows,
                cols,
                delay_ms,
                a_block,
                x,
            } => {
                e.u8(TAG_TASK_ASSIGN);
                e.u32(*task);
                e.u32(*coded_start);
                e.u32(*rows);
                e.u32(*cols);
                e.f64(*delay_ms);
                e.f32s(a_block);
                e.f32s(x);
            }
            Message::PartialResult {
                task,
                coded_start,
                rows,
                worker,
                delay_ms,
                values,
            } => {
                e.u8(TAG_PARTIAL_RESULT);
                e.u32(*task);
                e.u32(*coded_start);
                e.u32(*rows);
                e.u32(*worker);
                e.f64(*delay_ms);
                e.f32s(values);
            }
            Message::Cancel { task } => {
                e.u8(TAG_CANCEL);
                e.u32(*task);
            }
            Message::Heartbeat {
                nonce,
                rows_done,
                queue_depth,
                last_latency_ms,
            } => {
                e.u8(TAG_HEARTBEAT);
                e.u64(*nonce);
                e.u64(*rows_done);
                e.u32(*queue_depth);
                e.f64(*last_latency_ms);
            }
            Message::Shutdown {
                computed,
                skipped,
                disconnected,
                events,
            } => {
                e.u8(TAG_SHUTDOWN);
                e.u64(*computed);
                e.u64(*skipped);
                e.u8(u8::from(*disconnected));
                e.events(events);
            }
        }
        e.0
    }

    /// Decode one message; total over arbitrary byte slices.
    pub fn decode(buf: &[u8]) -> Result<Message, CodecError> {
        let mut d = Dec { buf, pos: 0 };
        let version = d.u8()?;
        if version != PROTOCOL_VERSION {
            return Err(CodecError::BadVersion {
                got: version,
                want: PROTOCOL_VERSION,
            });
        }
        let tag = d.u8()?;
        let msg = match tag {
            TAG_HELLO => Message::Hello {
                wid: d.u32()?,
                n_tasks: d.u32()?,
                n_cancel_slots: d.u32()?,
                time_scale: d.f64()?,
                beat_ms: d.f64()?,
            },
            TAG_TASK_ASSIGN => Message::TaskAssign {
                task: d.u32()?,
                coded_start: d.u32()?,
                rows: d.u32()?,
                cols: d.u32()?,
                delay_ms: d.f64()?,
                a_block: d.f32s()?,
                x: d.f32s()?,
            },
            TAG_PARTIAL_RESULT => Message::PartialResult {
                task: d.u32()?,
                coded_start: d.u32()?,
                rows: d.u32()?,
                worker: d.u32()?,
                delay_ms: d.f64()?,
                values: d.f32s()?,
            },
            TAG_CANCEL => Message::Cancel { task: d.u32()? },
            TAG_HEARTBEAT => Message::Heartbeat {
                nonce: d.u64()?,
                rows_done: d.u64()?,
                queue_depth: d.u32()?,
                last_latency_ms: d.f64()?,
            },
            TAG_SHUTDOWN => Message::Shutdown {
                computed: d.u64()?,
                skipped: d.u64()?,
                disconnected: d.flag()?,
                events: d.events()?,
            },
            other => return Err(CodecError::BadTag(other)),
        };
        d.finish()?;
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_messages() -> Vec<Message> {
        vec![
            Message::Hello {
                wid: 3,
                n_tasks: 7,
                n_cancel_slots: 2,
                time_scale: 1e-4,
                beat_ms: 25.0,
            },
            Message::TaskAssign {
                task: 1,
                coded_start: 64,
                rows: 2,
                cols: 3,
                delay_ms: 12.5,
                a_block: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                x: vec![0.5, -0.5, 2.0],
            },
            Message::PartialResult {
                task: 0,
                coded_start: 0,
                rows: 2,
                worker: 5,
                delay_ms: 3.25,
                values: vec![9.0, -9.0],
            },
            Message::Cancel { task: 9 },
            Message::Heartbeat {
                nonce: u64::MAX,
                rows_done: 512,
                queue_depth: 3,
                last_latency_ms: 7.5,
            },
            Message::Shutdown {
                computed: 4,
                skipped: 1,
                disconnected: true,
                events: vec![
                    WireEvent {
                        worker: 2,
                        task: 0,
                        rows: 8,
                        deadline_ms: 1.5,
                        compute_wall_ms: 0.25,
                        outcome: Outcome::Computed,
                    },
                    WireEvent {
                        worker: 2,
                        task: 1,
                        rows: 4,
                        deadline_ms: 2.5,
                        compute_wall_ms: 0.0,
                        outcome: Outcome::Cancelled,
                    },
                ],
            },
        ]
    }

    #[test]
    fn every_variant_roundtrips() {
        for m in sample_messages() {
            let bytes = m.encode();
            assert_eq!(Message::decode(&bytes).unwrap(), m, "{m:?}");
        }
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        for m in sample_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                let err = Message::decode(&bytes[..cut])
                    .expect_err("prefix must not decode");
                assert!(
                    matches!(
                        err,
                        CodecError::Truncated { .. } | CodecError::Oversize { .. }
                    ),
                    "cut at {cut} of {m:?}: {err:?}"
                );
            }
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = (Message::Cancel { task: 1 }).encode();
        bytes[0] = PROTOCOL_VERSION + 1;
        assert_eq!(
            Message::decode(&bytes),
            Err(CodecError::BadVersion {
                got: PROTOCOL_VERSION + 1,
                want: PROTOCOL_VERSION
            })
        );
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_rejected() {
        assert_eq!(
            Message::decode(&[PROTOCOL_VERSION, 200]),
            Err(CodecError::BadTag(200))
        );
        let mut bytes = (Message::Heartbeat { nonce: 7 }).encode();
        bytes.push(0);
        assert_eq!(Message::decode(&bytes), Err(CodecError::Trailing { extra: 1 }));
    }

    #[test]
    fn shutdown_flag_byte_is_strict() {
        let m = Message::Shutdown {
            computed: 1,
            skipped: 0,
            disconnected: false,
            events: Vec::new(),
        };
        let mut bytes = m.encode();
        // The flag sits right before the (empty) event list's 4-byte
        // length prefix.
        let flag_at = bytes.len() - 5;
        assert_eq!(bytes[flag_at], 0);
        bytes[flag_at] = 2;
        assert_eq!(Message::decode(&bytes), Err(CodecError::BadFlag(2)));
    }

    #[test]
    fn corrupt_length_prefix_cannot_drive_allocation() {
        // A PartialResult whose value count claims 1 billion elements:
        // decode must reject on the length check, before allocating.
        let mut e = Enc(Vec::new());
        e.u8(PROTOCOL_VERSION);
        e.u8(TAG_PARTIAL_RESULT);
        e.u32(0);
        e.u32(0);
        e.u32(1);
        e.u32(0);
        e.f64(0.0);
        e.u32(1_000_000_000); // length prefix with no payload behind it
        assert!(matches!(
            Message::decode(&e.0),
            Err(CodecError::Oversize { elems: 1_000_000_000, .. })
        ));
    }
}
