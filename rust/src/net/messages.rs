//! The shared wire vocabulary: one [`Message`] enum both sides of the
//! socket speak, with version-tagged binary encode/decode.
//!
//! Layout: every message body starts with `[version: u8][tag: u8]`,
//! then the variant's fields in declaration order — integers and IEEE
//! floats little-endian, vectors as a `u32` element count followed by
//! the elements. The version byte is checked on *every* decode, so a
//! coordinator and a worker from different protocol revisions fail the
//! handshake with a typed [`CodecError::BadVersion`] instead of
//! misparsing each other's frames.
//!
//! Decoding is total: any byte slice either decodes to exactly one
//! `Message` or returns a typed [`CodecError`] — truncation, unknown
//! tags, and corrupt length prefixes are errors, never panics, and a
//! length prefix is validated against the bytes actually present before
//! anything is allocated (fuzz-tested in `tests/net_socket.rs`).
//!
//! ## v2 → v3
//!
//! v3 extends `Hello` with a session id (resumable connections) and a
//! 32-byte auth digest (shared-secret handshake), and adds two
//! variants: [`Message::Resume`] (re-attach to a disconnected session)
//! and [`Message::TaskAssignChunk`] (stream one oversized `TaskAssign`
//! in bounded pieces). [`Message::decode`] is strict v3 — required for
//! the fuzz invariant that a lucky garbage decode re-encodes to the
//! bytes it consumed — while the worker-facing [`Message::decode_compat`]
//! additionally accepts v2 frames for the six v2 tags (a v2 `Hello`
//! resolves to session 0 / no auth), and [`Message::encode_legacy`]
//! renders replies a v2 peer can parse.

use crate::coordinator::worker::Outcome;

/// Protocol revision; bumped on any wire-incompatible change.
/// v3: `Hello` carries a session id + auth digest, `Resume` re-attaches
/// a broken connection without recomputing acked rows, and
/// `TaskAssignChunk` streams blocks near the frame cap in bounded
/// memory.
pub const PROTOCOL_VERSION: u8 = 3;

/// The previous revision, still understood by [`Message::decode_compat`]
/// (v2: progress heartbeats, beat cadence in `Hello`, `disconnected`
/// drain flag).
pub const LEGACY_VERSION: u8 = 2;

/// Auth digest width (bytes) carried in `Hello`/`Resume`.
pub const AUTH_LEN: usize = 32;

/// The "no token configured" digest: all zeros. [`auth_digest`] never
/// produces it for any token (the lane finalizer maps even the empty
/// string away from zero), so an unauthenticated peer cannot satisfy an
/// auth-requiring endpoint by luck or by sending zeros.
pub const NO_AUTH: [u8; AUTH_LEN] = [0u8; AUTH_LEN];

/// Per-message payload budget for chunked assignment streaming. One
/// `TaskAssign` whose encoding exceeds this is split into
/// [`Message::TaskAssignChunk`] frames of at most this many payload
/// bytes, so peak receive-side memory is one budget-sized piece plus
/// the growing reassembly buffer — never 2× the block as a single
/// monolithic frame would momentarily need.
pub const CHUNK_BUDGET: usize = 4 << 20;

/// Hard cap on a chunked reassembly (bytes): 4× the 64 MiB frame cap.
/// Chunking exists to carry blocks the single-frame cap cannot, but the
/// assembler still bounds what a hostile `of` count can make it buffer.
pub const MAX_ASSEMBLED: usize = 256 << 20;

/// Digest a shared-secret token for the `Hello`/`Resume` auth field:
/// four independent FNV-1a-64 lanes (distinct basis offsets, mixed
/// through a 64-bit finalizer) laid out little-endian. Not a
/// cryptographic MAC — the threat model is accidental cross-talk
/// between fleets and drive-by port scans, matching the repo's
/// no-external-dependency rule — but collision-resistant enough that a
/// wrong token never passes by accident.
pub fn auth_digest(token: &str) -> [u8; AUTH_LEN] {
    let mut out = [0u8; AUTH_LEN];
    for lane in 0u64..4 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ (lane + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        for &b in token.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        // splitmix-style finalizer: decorrelates lanes on short tokens
        // and maps every input (including "") away from zero.
        h ^= h >> 33;
        h = h.wrapping_mul(0xff51_afd7_ed55_8ccd);
        h ^= h >> 33;
        h = h.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
        h ^= h >> 33;
        let i = lane as usize * 8;
        out[i..i + 8].copy_from_slice(&h.to_le_bytes());
    }
    out
}

/// Constant-time digest comparison: the OR-fold touches every byte
/// regardless of where the first mismatch sits, so response timing
/// leaks nothing about how much of a guessed digest was right.
pub fn constant_time_eq(a: &[u8; AUTH_LEN], b: &[u8; AUTH_LEN]) -> bool {
    let mut diff = 0u8;
    for i in 0..AUTH_LEN {
        diff |= a[i] ^ b[i];
    }
    diff == 0
}

/// One worker-side task event as carried in [`Message::Shutdown`] — the
/// wire twin of [`crate::coordinator::worker::TaskEvent`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireEvent {
    pub worker: u32,
    /// Cancel-slot id (the coordinator's flat task id).
    pub task: u32,
    pub rows: u32,
    pub deadline_ms: f64,
    pub compute_wall_ms: f64,
    pub outcome: Outcome,
}

/// Everything that crosses the coordinator ↔ worker wire.
///
/// Lifecycle: coordinator connects and sends `Hello` (answered by a
/// `Hello` ack), then `n_tasks` × `TaskAssign` (each possibly split
/// into `TaskAssignChunk` frames), then one `Heartbeat` as the start
/// barrier. The worker streams `PartialResult`s as deadlines fire; the
/// coordinator sends `Cancel` the moment a task decodes. When the
/// worker's queue drains it sends `Shutdown` carrying its drain stats
/// and event log, and the coordinator answers `Shutdown` to release the
/// connection. A connection opening with `Resume` instead of `Hello`
/// re-attaches to a previously disconnected session and replays its
/// unacked results (see `net::worker`).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Handshake (both directions). Coordinator → worker it announces
    /// the logical worker id, the task count to expect, the size of the
    /// cancellation table, the virtual-time scale, the heartbeat
    /// cadence it wants (`beat_ms ≤ 0` disables recurring beats), a
    /// session id (`0` = not resumable) and the auth digest; worker →
    /// coordinator it acknowledges (counts reused as reply codes on the
    /// `Resume` path, zeroed otherwise).
    Hello {
        wid: u32,
        n_tasks: u32,
        n_cancel_slots: u32,
        time_scale: f64,
        beat_ms: f64,
        /// Nonzero marks the connection resumable: the worker keeps
        /// computing across a disconnect and parks unsent results under
        /// this id for a later [`Message::Resume`].
        session: u64,
        /// [`auth_digest`] of the shared token; [`NO_AUTH`] when no
        /// token is configured.
        auth: [u8; AUTH_LEN],
    },
    /// One coded row-block assignment (the wire twin of
    /// [`crate::coordinator::worker::SubTask`]).
    TaskAssign {
        /// Cancel-slot id (flat `(job, master)` id in stream mode).
        task: u32,
        coded_start: u32,
        rows: u32,
        cols: u32,
        /// Sampled virtual deadline (ms).
        delay_ms: f64,
        /// Row-major `rows × cols` coded block.
        a_block: Vec<f32>,
        /// Model vector (`cols`).
        x: Vec<f32>,
    },
    /// Computed products for one sub-task (worker → coordinator).
    PartialResult {
        task: u32,
        coded_start: u32,
        rows: u32,
        worker: u32,
        delay_ms: f64,
        values: Vec<f32>,
    },
    /// Stop work for one task (coordinator → worker): its master
    /// decoded. Honored between sub-tasks mid-run.
    Cancel { task: u32 },
    /// Liveness + progress beat. Coordinator → worker (fields zeroed)
    /// it is the post-assignment start barrier; worker → coordinator it
    /// is the recurring health beat carrying rows completed so far, the
    /// remaining queue depth and the worker's last observed per-task
    /// wall latency — the feed `health::HealthTracker` scores.
    Heartbeat {
        nonce: u64,
        rows_done: u64,
        queue_depth: u32,
        last_latency_ms: f64,
    },
    /// Graceful teardown. Worker → coordinator it carries the drain
    /// stats + event log, with `disconnected` marking a drain forced by
    /// an unexpected coordinator-side disconnect (vs. a clean
    /// coordinator-initiated `Shutdown` or natural queue completion);
    /// coordinator → worker (fields zeroed) it acknowledges and
    /// releases the connection. Received mid-run it cancels everything
    /// outstanding (drain).
    Shutdown {
        computed: u64,
        skipped: u64,
        disconnected: bool,
        events: Vec<WireEvent>,
    },
    /// Re-attach to a disconnected session (coordinator → worker, v3
    /// only, sent INSTEAD of `Hello` as a connection's first frame).
    /// The worker answers with a `Hello` whose `n_cancel_slots` is a
    /// reply code — see `net::worker::{RESUME_MISS, RESUME_PARKED,
    /// RESUME_RUNNING}` — then, on a hit, replays every parked
    /// `PartialResult` past `last_acked_row` and closes with its
    /// `Shutdown` drain stats.
    Resume {
        session_id: u64,
        /// Coded rows the coordinator had absorbed from this session
        /// before it broke; replay skips results entirely below this
        /// watermark (the worker never recomputes acked rows).
        last_acked_row: u64,
        auth: [u8; AUTH_LEN],
    },
    /// One bounded piece of an oversized `TaskAssign` encoding (v3
    /// only). `seq` ∈ `0..of` strictly in order (TCP preserves order —
    /// any gap, duplicate or reorder is a protocol violation, rejected
    /// typed); the concatenated payloads decode as one `TaskAssign`.
    TaskAssignChunk { seq: u32, of: u32, payload: Vec<u8> },
}

/// Message-level decode failure. Every variant is reachable from a
/// hostile or truncated byte slice; none of them panic.
#[derive(Clone, Debug, PartialEq)]
pub enum CodecError {
    /// Fewer bytes than the field at `offset` needs.
    Truncated {
        offset: usize,
        needed: usize,
        have: usize,
    },
    /// Version byte mismatch (incompatible peer).
    BadVersion { got: u8, want: u8 },
    /// Unknown message tag.
    BadTag(u8),
    /// Unknown outcome discriminant inside an event record.
    BadOutcome(u8),
    /// A boolean field byte other than 0 or 1 (a lucky garbage decode
    /// must still re-encode identically, so flags are strict).
    BadFlag(u8),
    /// A length prefix announced more elements than the remaining bytes
    /// can hold.
    Oversize { elems: usize, have: usize },
    /// Bytes left over after a complete message.
    Trailing { extra: usize },
    /// The peer's auth digest does not match the configured token.
    AuthFailed,
    /// A chunk arrived out of order (`want` was expected next).
    ChunkSequence { got: u32, want: u32 },
    /// A chunk's `of` count is zero or disagrees with the reassembly
    /// in progress.
    ChunkCount { got: u32, want: u32 },
    /// Reassembled size would exceed [`MAX_ASSEMBLED`].
    ChunkOversize { total: usize, cap: usize },
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Truncated {
                offset,
                needed,
                have,
            } => write!(
                f,
                "message truncated at byte {offset}: need {needed}, have {have}"
            ),
            CodecError::BadVersion { got, want } => {
                write!(f, "protocol version {got} (this build speaks {want})")
            }
            CodecError::BadTag(t) => write!(f, "unknown message tag {t}"),
            CodecError::BadOutcome(o) => write!(f, "unknown outcome discriminant {o}"),
            CodecError::BadFlag(b) => write!(f, "flag byte {b} is neither 0 nor 1"),
            CodecError::Oversize { elems, have } => {
                write!(f, "length prefix {elems} exceeds remaining {have} bytes")
            }
            CodecError::Trailing { extra } => {
                write!(f, "{extra} trailing bytes after message")
            }
            CodecError::AuthFailed => write!(f, "authentication failed (wrong or missing token)"),
            CodecError::ChunkSequence { got, want } => {
                write!(f, "chunk seq {got} arrived, expected {want}")
            }
            CodecError::ChunkCount { got, want } => {
                write!(f, "chunk count {got} disagrees with {want}")
            }
            CodecError::ChunkOversize { total, cap } => {
                write!(f, "reassembled chunk size {total} exceeds cap {cap}")
            }
        }
    }
}

impl std::error::Error for CodecError {}

const TAG_HELLO: u8 = 0;
const TAG_TASK_ASSIGN: u8 = 1;
const TAG_PARTIAL_RESULT: u8 = 2;
const TAG_CANCEL: u8 = 3;
const TAG_HEARTBEAT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
const TAG_RESUME: u8 = 6;
const TAG_TASK_ASSIGN_CHUNK: u8 = 7;

/// Bytes per encoded [`WireEvent`]: worker + task + rows (u32) +
/// deadline + compute wall (f64) + outcome (u8).
const EVENT_BYTES: usize = 4 + 4 + 4 + 8 + 8 + 1;

fn outcome_to_u8(o: Outcome) -> u8 {
    match o {
        Outcome::Computed => 0,
        Outcome::Cancelled => 1,
        Outcome::Failed => 2,
    }
}

fn outcome_from_u8(b: u8) -> Result<Outcome, CodecError> {
    match b {
        0 => Ok(Outcome::Computed),
        1 => Ok(Outcome::Cancelled),
        2 => Ok(Outcome::Failed),
        other => Err(CodecError::BadOutcome(other)),
    }
}

// ---- encoding -----------------------------------------------------------

pub(crate) struct Enc(pub(crate) Vec<u8>);

impl Enc {
    pub(crate) fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    pub(crate) fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    pub(crate) fn f64(&mut self, v: f64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        for &x in xs {
            self.0.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn bytes(&mut self, xs: &[u8]) {
        self.u32(xs.len() as u32);
        self.0.extend_from_slice(xs);
    }
    fn raw(&mut self, xs: &[u8]) {
        self.0.extend_from_slice(xs);
    }
    fn events(&mut self, evs: &[WireEvent]) {
        self.u32(evs.len() as u32);
        for e in evs {
            self.u32(e.worker);
            self.u32(e.task);
            self.u32(e.rows);
            self.f64(e.deadline_ms);
            self.f64(e.compute_wall_ms);
            self.u8(outcome_to_u8(e.outcome));
        }
    }
}

// ---- decoding -----------------------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take<const N: usize>(&mut self) -> Result<[u8; N], CodecError> {
        if self.remaining() < N {
            return Err(CodecError::Truncated {
                offset: self.pos,
                needed: N,
                have: self.remaining(),
            });
        }
        let mut out = [0u8; N];
        out.copy_from_slice(&self.buf[self.pos..self.pos + N]);
        self.pos += N;
        Ok(out)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take::<1>()?[0])
    }
    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take::<4>()?))
    }
    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take::<8>()?))
    }
    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_le_bytes(self.take::<8>()?))
    }

    /// Strict boolean: any byte other than 0/1 is a typed error so
    /// decode(encode(m)) == m implies encode(decode(b)) == b.
    fn flag(&mut self) -> Result<bool, CodecError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(CodecError::BadFlag(other)),
        }
    }

    /// Length prefix validated against remaining bytes BEFORE the
    /// allocation, so a corrupt prefix cannot drive an OOM.
    fn len_prefix(&mut self, elem_bytes: usize) -> Result<usize, CodecError> {
        let n = self.u32()? as usize;
        match n.checked_mul(elem_bytes) {
            Some(need) if need <= self.remaining() => Ok(n),
            _ => Err(CodecError::Oversize {
                elems: n,
                have: self.remaining(),
            }),
        }
    }

    fn f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.len_prefix(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(f32::from_le_bytes(self.take::<4>()?));
        }
        Ok(out)
    }

    fn bytes(&mut self) -> Result<Vec<u8>, CodecError> {
        let n = self.len_prefix(1)?;
        let out = self.buf[self.pos..self.pos + n].to_vec();
        self.pos += n;
        Ok(out)
    }

    fn events(&mut self) -> Result<Vec<WireEvent>, CodecError> {
        let n = self.len_prefix(EVENT_BYTES)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(WireEvent {
                worker: self.u32()?,
                task: self.u32()?,
                rows: self.u32()?,
                deadline_ms: self.f64()?,
                compute_wall_ms: self.f64()?,
                outcome: outcome_from_u8(self.u8()?)?,
            });
        }
        Ok(out)
    }

    fn finish(self) -> Result<(), CodecError> {
        if self.remaining() > 0 {
            return Err(CodecError::Trailing {
                extra: self.remaining(),
            });
        }
        Ok(())
    }
}

impl Message {
    /// Serialize to the version-tagged binary layout (current protocol).
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc(Vec::with_capacity(16));
        e.u8(PROTOCOL_VERSION);
        self.encode_body(&mut e, false);
        e.0
    }

    /// Serialize for a v2 peer: the version byte is [`LEGACY_VERSION`]
    /// and `Hello` omits the v3 session/auth tail. `None` for the two
    /// v3-only variants (`Resume`, `TaskAssignChunk`) — a v2 peer has
    /// no parse for them, so callers must not send them.
    pub fn encode_legacy(&self) -> Option<Vec<u8>> {
        if matches!(
            self,
            Message::Resume { .. } | Message::TaskAssignChunk { .. }
        ) {
            return None;
        }
        let mut e = Enc(Vec::with_capacity(16));
        e.u8(LEGACY_VERSION);
        self.encode_body(&mut e, true);
        Some(e.0)
    }

    fn encode_body(&self, e: &mut Enc, legacy: bool) {
        match self {
            Message::Hello {
                wid,
                n_tasks,
                n_cancel_slots,
                time_scale,
                beat_ms,
                session,
                auth,
            } => {
                e.u8(TAG_HELLO);
                e.u32(*wid);
                e.u32(*n_tasks);
                e.u32(*n_cancel_slots);
                e.f64(*time_scale);
                e.f64(*beat_ms);
                if !legacy {
                    e.u64(*session);
                    e.raw(auth);
                }
            }
            Message::TaskAssign {
                task,
                coded_start,
                rows,
                cols,
                delay_ms,
                a_block,
                x,
            } => {
                e.u8(TAG_TASK_ASSIGN);
                e.u32(*task);
                e.u32(*coded_start);
                e.u32(*rows);
                e.u32(*cols);
                e.f64(*delay_ms);
                e.f32s(a_block);
                e.f32s(x);
            }
            Message::PartialResult {
                task,
                coded_start,
                rows,
                worker,
                delay_ms,
                values,
            } => {
                e.u8(TAG_PARTIAL_RESULT);
                e.u32(*task);
                e.u32(*coded_start);
                e.u32(*rows);
                e.u32(*worker);
                e.f64(*delay_ms);
                e.f32s(values);
            }
            Message::Cancel { task } => {
                e.u8(TAG_CANCEL);
                e.u32(*task);
            }
            Message::Heartbeat {
                nonce,
                rows_done,
                queue_depth,
                last_latency_ms,
            } => {
                e.u8(TAG_HEARTBEAT);
                e.u64(*nonce);
                e.u64(*rows_done);
                e.u32(*queue_depth);
                e.f64(*last_latency_ms);
            }
            Message::Shutdown {
                computed,
                skipped,
                disconnected,
                events,
            } => {
                e.u8(TAG_SHUTDOWN);
                e.u64(*computed);
                e.u64(*skipped);
                e.u8(u8::from(*disconnected));
                e.events(events);
            }
            Message::Resume {
                session_id,
                last_acked_row,
                auth,
            } => {
                e.u8(TAG_RESUME);
                e.u64(*session_id);
                e.u64(*last_acked_row);
                e.raw(auth);
            }
            Message::TaskAssignChunk { seq, of, payload } => {
                e.u8(TAG_TASK_ASSIGN_CHUNK);
                e.u32(*seq);
                e.u32(*of);
                e.bytes(payload);
            }
        }
    }

    /// Decode one message; total over arbitrary byte slices. Strict
    /// current-version only — peers a revision behind go through
    /// [`Message::decode_compat`] on the worker side.
    pub fn decode(buf: &[u8]) -> Result<Message, CodecError> {
        Self::decode_with(buf, false)
    }

    /// Decode accepting [`LEGACY_VERSION`] frames too (v2 carries only
    /// the six original tags; its `Hello` resolves to `session: 0`,
    /// `auth: NO_AUTH`). Used by the worker so one fleet can mix
    /// coordinator revisions during a rolling upgrade.
    pub fn decode_compat(buf: &[u8]) -> Result<Message, CodecError> {
        Self::decode_with(buf, true)
    }

    fn decode_with(buf: &[u8], allow_legacy: bool) -> Result<Message, CodecError> {
        let mut d = Dec { buf, pos: 0 };
        let version = d.u8()?;
        let legacy = version == LEGACY_VERSION && allow_legacy;
        if version != PROTOCOL_VERSION && !legacy {
            return Err(CodecError::BadVersion {
                got: version,
                want: PROTOCOL_VERSION,
            });
        }
        let tag = d.u8()?;
        let msg = match tag {
            TAG_HELLO => Message::Hello {
                wid: d.u32()?,
                n_tasks: d.u32()?,
                n_cancel_slots: d.u32()?,
                time_scale: d.f64()?,
                beat_ms: d.f64()?,
                session: if legacy { 0 } else { d.u64()? },
                auth: if legacy { NO_AUTH } else { d.take::<AUTH_LEN>()? },
            },
            TAG_TASK_ASSIGN => Message::TaskAssign {
                task: d.u32()?,
                coded_start: d.u32()?,
                rows: d.u32()?,
                cols: d.u32()?,
                delay_ms: d.f64()?,
                a_block: d.f32s()?,
                x: d.f32s()?,
            },
            TAG_PARTIAL_RESULT => Message::PartialResult {
                task: d.u32()?,
                coded_start: d.u32()?,
                rows: d.u32()?,
                worker: d.u32()?,
                delay_ms: d.f64()?,
                values: d.f32s()?,
            },
            TAG_CANCEL => Message::Cancel { task: d.u32()? },
            TAG_HEARTBEAT => Message::Heartbeat {
                nonce: d.u64()?,
                rows_done: d.u64()?,
                queue_depth: d.u32()?,
                last_latency_ms: d.f64()?,
            },
            TAG_SHUTDOWN => Message::Shutdown {
                computed: d.u64()?,
                skipped: d.u64()?,
                disconnected: d.flag()?,
                events: d.events()?,
            },
            // v3-only tags: a v2 frame carrying them is malformed.
            TAG_RESUME if !legacy => Message::Resume {
                session_id: d.u64()?,
                last_acked_row: d.u64()?,
                auth: d.take::<AUTH_LEN>()?,
            },
            TAG_TASK_ASSIGN_CHUNK if !legacy => Message::TaskAssignChunk {
                seq: d.u32()?,
                of: d.u32()?,
                payload: d.bytes()?,
            },
            other => return Err(CodecError::BadTag(other)),
        };
        d.finish()?;
        Ok(msg)
    }
}

/// Reassembles a chunked `TaskAssign` from its in-order
/// [`Message::TaskAssignChunk`] pieces. TCP delivers frames in send
/// order, so the assembler is strict: the only accepted `seq` is the
/// next expected one — a duplicate, gap or reorder is a typed protocol
/// error, and any error resets the assembly (the connection is about to
/// be torn down anyway). [`ChunkAssembler::push`] returns the
/// concatenated payload when the final piece lands; the caller decodes
/// it as a complete message and must reject anything but `TaskAssign`
/// (no recursive chunking).
#[derive(Debug, Default)]
pub struct ChunkAssembler {
    buf: Vec<u8>,
    next: u32,
    of: u32,
}

impl ChunkAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    /// A reassembly has started and is incomplete.
    pub fn in_progress(&self) -> bool {
        self.of != 0
    }

    fn reset(&mut self) {
        self.buf = Vec::new();
        self.next = 0;
        self.of = 0;
    }

    /// Feed one chunk. `Ok(Some(bytes))` when this piece completed the
    /// message; `Ok(None)` when more pieces are expected.
    pub fn push(
        &mut self,
        seq: u32,
        of: u32,
        payload: &[u8],
    ) -> Result<Option<Vec<u8>>, CodecError> {
        if of == 0 {
            self.reset();
            return Err(CodecError::ChunkCount { got: 0, want: 1 });
        }
        if self.of == 0 {
            self.of = of;
        } else if of != self.of {
            let want = self.of;
            self.reset();
            return Err(CodecError::ChunkCount { got: of, want });
        }
        if seq != self.next {
            let want = self.next;
            self.reset();
            return Err(CodecError::ChunkSequence { got: seq, want });
        }
        if self.buf.len().saturating_add(payload.len()) > MAX_ASSEMBLED {
            let total = self.buf.len().saturating_add(payload.len());
            self.reset();
            return Err(CodecError::ChunkOversize {
                total,
                cap: MAX_ASSEMBLED,
            });
        }
        self.buf.extend_from_slice(payload);
        self.next += 1;
        if self.next == self.of {
            let out = std::mem::take(&mut self.buf);
            self.reset();
            Ok(Some(out))
        } else {
            Ok(None)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_messages() -> Vec<Message> {
        vec![
            Message::Hello {
                wid: 3,
                n_tasks: 7,
                n_cancel_slots: 2,
                time_scale: 1e-4,
                beat_ms: 25.0,
                session: 0xdead_beef_0042,
                auth: auth_digest("sesame"),
            },
            Message::TaskAssign {
                task: 1,
                coded_start: 64,
                rows: 2,
                cols: 3,
                delay_ms: 12.5,
                a_block: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
                x: vec![0.5, -0.5, 2.0],
            },
            Message::PartialResult {
                task: 0,
                coded_start: 0,
                rows: 2,
                worker: 5,
                delay_ms: 3.25,
                values: vec![9.0, -9.0],
            },
            Message::Cancel { task: 9 },
            Message::Heartbeat {
                nonce: u64::MAX,
                rows_done: 512,
                queue_depth: 3,
                last_latency_ms: 7.5,
            },
            Message::Shutdown {
                computed: 4,
                skipped: 1,
                disconnected: true,
                events: vec![
                    WireEvent {
                        worker: 2,
                        task: 0,
                        rows: 8,
                        deadline_ms: 1.5,
                        compute_wall_ms: 0.25,
                        outcome: Outcome::Computed,
                    },
                    WireEvent {
                        worker: 2,
                        task: 1,
                        rows: 4,
                        deadline_ms: 2.5,
                        compute_wall_ms: 0.0,
                        outcome: Outcome::Cancelled,
                    },
                ],
            },
            Message::Resume {
                session_id: 777,
                last_acked_row: 96,
                auth: NO_AUTH,
            },
            Message::TaskAssignChunk {
                seq: 2,
                of: 5,
                payload: vec![1, 2, 3, 4, 5],
            },
        ]
    }

    #[test]
    fn every_variant_roundtrips() {
        for m in sample_messages() {
            let bytes = m.encode();
            assert_eq!(Message::decode(&bytes).unwrap(), m, "{m:?}");
        }
    }

    #[test]
    fn every_truncation_is_a_typed_error() {
        for m in sample_messages() {
            let bytes = m.encode();
            for cut in 0..bytes.len() {
                let err = Message::decode(&bytes[..cut])
                    .expect_err("prefix must not decode");
                assert!(
                    matches!(
                        err,
                        CodecError::Truncated { .. } | CodecError::Oversize { .. }
                    ),
                    "cut at {cut} of {m:?}: {err:?}"
                );
            }
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = (Message::Cancel { task: 1 }).encode();
        bytes[0] = PROTOCOL_VERSION + 1;
        assert_eq!(
            Message::decode(&bytes),
            Err(CodecError::BadVersion {
                got: PROTOCOL_VERSION + 1,
                want: PROTOCOL_VERSION
            })
        );
        // Strict decode rejects even the supported legacy revision …
        bytes[0] = LEGACY_VERSION;
        assert!(matches!(
            Message::decode(&bytes),
            Err(CodecError::BadVersion { got: LEGACY_VERSION, .. })
        ));
        // … while compat decode accepts it (Cancel's layout is shared).
        assert_eq!(
            Message::decode_compat(&bytes).unwrap(),
            Message::Cancel { task: 1 }
        );
    }

    #[test]
    fn unknown_tag_and_trailing_bytes_rejected() {
        assert_eq!(
            Message::decode(&[PROTOCOL_VERSION, 200]),
            Err(CodecError::BadTag(200))
        );
        let mut bytes = (Message::Heartbeat {
            nonce: 7,
            rows_done: 0,
            queue_depth: 0,
            last_latency_ms: 0.0,
        })
        .encode();
        bytes.push(0);
        assert_eq!(Message::decode(&bytes), Err(CodecError::Trailing { extra: 1 }));
    }

    #[test]
    fn shutdown_flag_byte_is_strict() {
        let m = Message::Shutdown {
            computed: 1,
            skipped: 0,
            disconnected: false,
            events: Vec::new(),
        };
        let mut bytes = m.encode();
        // The flag sits right before the (empty) event list's 4-byte
        // length prefix.
        let flag_at = bytes.len() - 5;
        assert_eq!(bytes[flag_at], 0);
        bytes[flag_at] = 2;
        assert_eq!(Message::decode(&bytes), Err(CodecError::BadFlag(2)));
    }

    #[test]
    fn corrupt_length_prefix_cannot_drive_allocation() {
        // A PartialResult whose value count claims 1 billion elements:
        // decode must reject on the length check, before allocating.
        let mut e = Enc(Vec::new());
        e.u8(PROTOCOL_VERSION);
        e.u8(TAG_PARTIAL_RESULT);
        e.u32(0);
        e.u32(0);
        e.u32(1);
        e.u32(0);
        e.f64(0.0);
        e.u32(1_000_000_000); // length prefix with no payload behind it
        assert!(matches!(
            Message::decode(&e.0),
            Err(CodecError::Oversize { elems: 1_000_000_000, .. })
        ));
    }

    #[test]
    fn legacy_hello_decodes_without_session_or_auth() {
        // A v2 Hello, byte-built the way a v2 build would: no session,
        // no auth tail.
        let mut e = Enc(Vec::new());
        e.u8(LEGACY_VERSION);
        e.u8(TAG_HELLO);
        e.u32(4); // wid
        e.u32(9); // n_tasks
        e.u32(2); // n_cancel_slots
        e.f64(1e-4);
        e.f64(25.0);
        let m = Message::decode_compat(&e.0).unwrap();
        assert_eq!(
            m,
            Message::Hello {
                wid: 4,
                n_tasks: 9,
                n_cancel_slots: 2,
                time_scale: 1e-4,
                beat_ms: 25.0,
                session: 0,
                auth: NO_AUTH,
            }
        );
        // And the legacy re-encode reproduces the v2 bytes exactly.
        assert_eq!(m.encode_legacy().unwrap(), e.0);
        // Strict decode refuses the v2 frame.
        assert!(matches!(
            Message::decode(&e.0),
            Err(CodecError::BadVersion { got: LEGACY_VERSION, .. })
        ));
    }

    #[test]
    fn v3_only_tags_are_rejected_on_legacy_frames() {
        for msg in [
            Message::Resume {
                session_id: 1,
                last_acked_row: 0,
                auth: NO_AUTH,
            },
            Message::TaskAssignChunk {
                seq: 0,
                of: 1,
                payload: vec![0],
            },
        ] {
            assert_eq!(msg.encode_legacy(), None, "{msg:?}");
            let mut bytes = msg.encode();
            bytes[0] = LEGACY_VERSION;
            assert!(
                matches!(Message::decode_compat(&bytes), Err(CodecError::BadTag(_))),
                "{msg:?}"
            );
        }
    }

    #[test]
    fn auth_digest_is_stable_and_token_sensitive() {
        let a = auth_digest("sesame");
        assert_eq!(a, auth_digest("sesame"), "digest must be deterministic");
        assert_ne!(a, auth_digest("sesame "), "whitespace must matter");
        assert_ne!(a, auth_digest("Sesame"), "case must matter");
        // No token ever digests to the all-zero NO_AUTH sentinel.
        assert_ne!(auth_digest(""), NO_AUTH);
        assert!(constant_time_eq(&a, &auth_digest("sesame")));
        assert!(!constant_time_eq(&a, &NO_AUTH));
    }

    #[test]
    fn chunk_assembler_reassembles_in_order() {
        let payload: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let mut asm = ChunkAssembler::new();
        let pieces: Vec<&[u8]> = payload.chunks(300).collect();
        let of = pieces.len() as u32;
        let mut out = None;
        for (i, p) in pieces.iter().enumerate() {
            assert!(!matches!(out, Some(_)));
            out = asm.push(i as u32, of, p).unwrap();
        }
        assert_eq!(out.unwrap(), payload);
        assert!(!asm.in_progress(), "assembler must reset after completion");
    }

    #[test]
    fn chunk_assembler_rejects_gaps_duplicates_and_bad_counts() {
        // Gap: seq 1 first.
        let mut asm = ChunkAssembler::new();
        assert_eq!(
            asm.push(1, 3, b"x"),
            Err(CodecError::ChunkSequence { got: 1, want: 0 })
        );
        // Duplicate: 0 then 0 again.
        let mut asm = ChunkAssembler::new();
        asm.push(0, 3, b"x").unwrap();
        assert_eq!(
            asm.push(0, 3, b"y"),
            Err(CodecError::ChunkSequence { got: 0, want: 1 })
        );
        // `of` flips mid-assembly.
        let mut asm = ChunkAssembler::new();
        asm.push(0, 3, b"x").unwrap();
        assert_eq!(
            asm.push(1, 4, b"y"),
            Err(CodecError::ChunkCount { got: 4, want: 3 })
        );
        // Zero count.
        let mut asm = ChunkAssembler::new();
        assert_eq!(
            asm.push(0, 0, b"x"),
            Err(CodecError::ChunkCount { got: 0, want: 1 })
        );
        // Every error resets: a fresh, correct assembly then succeeds.
        assert!(!asm.in_progress());
        assert_eq!(asm.push(0, 1, b"ok").unwrap().unwrap(), b"ok");
    }
}
