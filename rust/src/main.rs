fn main() -> anyhow::Result<()> { coded_coop::cli::run() }
