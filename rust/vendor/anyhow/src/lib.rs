//! Minimal, offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so the subset of the
//! `anyhow` 1.x API this workspace actually uses is vendored here:
//!
//! * [`Error`] — opaque boxed error with `Display`/`Debug`;
//! * [`Result`] — `Result<T, Error>` alias with a default type parameter;
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors;
//! * a blanket `From<E: std::error::Error + Send + Sync + 'static>` so
//!   `?` converts any standard error (exactly like the real crate).
//!
//! Semantics match `anyhow` for every call site in this repository; the
//! crates.io release can be swapped in without source changes.

use std::error::Error as StdError;
use std::fmt;

/// Opaque error: a boxed `std::error::Error` trait object.
///
/// Like the real `anyhow::Error`, this type deliberately does NOT
/// implement `std::error::Error` itself — that is what makes the blanket
/// `From` impl below coherent.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M>(message: M) -> Self
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error {
            inner: Box::new(MessageError(message.to_string())),
        }
    }

    /// Borrow the underlying error trait object.
    pub fn as_dyn(&self) -> &(dyn StdError + Send + Sync + 'static) {
        &*self.inner
    }

    /// The lowest-level source of this error.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cur: &(dyn StdError + 'static) = &*self.inner;
        while let Some(src) = cur.source() {
            cur = src;
        }
        cur
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow renders the display chain for Debug; do the same so
        // `.unwrap()` failures read well in tests.
        write!(f, "{}", self.inner)?;
        let mut src = self.inner.source();
        while let Some(s) = src {
            write!(f, "\n\nCaused by:\n    {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error { inner: Box::new(e) }
    }
}

/// Plain-string error used by [`Error::msg`] and the [`anyhow!`] macro.
struct MessageError(String);

impl fmt::Display for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for MessageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for MessageError {}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: `", stringify!($cond), "`"));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<u32> {
        ensure!(flag, "flag was {flag}");
        Ok(7)
    }

    #[test]
    fn macros_and_conversions() {
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        assert!(fails(true).is_ok());
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");

        // `?` converts std errors.
        fn io() -> Result<String> {
            Ok(std::fs::read_to_string("/definitely/not/a/file")?)
        }
        assert!(io().is_err());
    }

    #[test]
    fn identity_question_mark() {
        fn outer() -> Result<u32> {
            let v = fails(true)?;
            Ok(v + 1)
        }
        assert_eq!(outer().unwrap(), 8);
    }
}
