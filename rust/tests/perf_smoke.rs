//! Perf-trajectory smoke: a miniature of `benches/engine.rs` that runs
//! under plain `cargo test -q`, so `BENCH_engine.json` lands at the repo
//! root on every test run — the trajectory never depends on someone
//! remembering `cargo bench`. (`cargo bench --bench engine` overwrites
//! the file with full-length measurements; the record notes its source.)
//!
//! Deliberately NO timing assertions here: wall-clock ratios on a busy
//! test machine are flaky. The relative old-vs-new gate runs in CI on
//! the bench output (`python/bench_gate.py`), where the two kernels are
//! measured back-to-back under the same load.

use std::time::Duration;

use coded_coop::assign::ValueModel;
use coded_coop::config::{CommModel, Scenario};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::engine::oracle;
use coded_coop::sim::{self, McOptions, SampleOrder};
use coded_coop::util::benchkit::{repo_root_record, write_json, Bench};
use coded_coop::util::json;

#[test]
fn perf_trajectory_lands_at_repo_root() {
    let out_path = repo_root_record("BENCH_engine.json");
    let trials = 2_000usize;
    let s = Scenario::small_scale(2022, 2.0, CommModel::Stochastic);
    let p = plan::build(
        &s,
        &PlanSpec {
            policy: Policy::DediIter,
            values: ValueModel::Markov,
            loads: LoadMethod::Markov,
        },
    );
    let o = McOptions {
        trials,
        seed: 2022,
        keep_samples: false,
        threads: 1,
        ziggurat: false,
    };
    let bench = || {
        Bench::new()
            .warmup(Duration::from_millis(30))
            .measure_time(Duration::from_millis(150))
            .items(trials as f64)
    };
    let results = vec![
        bench().run("small/legacy", || oracle::run(&s, &p, &o).system.mean()),
        bench().run("small/v2-trial-major", || {
            sim::run_ordered(&s, &p, &o, SampleOrder::TrialMajor).system.mean()
        }),
        bench().run("small/v2-blocked", || {
            sim::run_ordered(&s, &p, &o, SampleOrder::Blocked).system.mean()
        }),
        bench().run("small/v3-chunked", || {
            sim::run_ordered(&s, &p, &o, SampleOrder::Chunked).system.mean()
        }),
        bench().run("small/v3-zigg", || {
            let oz = McOptions { ziggurat: true, ..o };
            sim::run_ordered(&s, &p, &oz, SampleOrder::Chunked).system.mean()
        }),
    ];
    write_json(
        &out_path,
        "engine (test smoke — rerun `cargo bench --bench engine` for full numbers)",
        &results,
    )
    .expect("write BENCH_engine.json at the repo root");

    // The record must parse back and carry a throughput figure per row —
    // that is what the CI gate and the trajectory consume.
    let text = std::fs::read_to_string(&out_path).unwrap();
    let j = json::parse(&text).unwrap();
    let rows = j.get("results").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 5);
    for row in rows {
        let tput = row.get("items_per_sec").unwrap().as_f64().unwrap();
        assert!(tput > 0.0, "trials/s must be positive");
        let name = row.get("name").unwrap().as_str().unwrap();
        assert!(name.starts_with("small/"), "scenario/kernel naming: {name}");
    }
}
