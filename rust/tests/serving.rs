//! Serving-layer acceptance tests: batch-engine parity, plan-cache
//! equivalence, churn edges, and the serving sweep end-to-end.

use coded_coop::assign::ValueModel;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::experiment::{self, catalog};
use coded_coop::policy::PolicySpec;
use coded_coop::serve::{
    self, ChurnAction, ChurnEvent, ChurnScript, EventQueueKind, ServeConfig, ServiceStreams,
};
use coded_coop::sim::{self, McOptions};

fn policy(loads: &str) -> PolicySpec {
    PolicySpec::new("dedi-iter", ValueModel::Markov, loads)
}

fn cfg(loads: &str) -> ServeConfig {
    ServeConfig::new(policy(loads))
}

/// The headline acceptance pin: with constant shares and no churn, a
/// single-master serve run's per-job service delays reproduce the batch
/// `sim::run` completion delays bit-for-bit on the same seed — queueing
/// included (the FIFO queue changes start times, never the draws).
#[test]
fn constant_share_serve_matches_batch_engine_bit_for_bit_single_master() {
    let s = Scenario::random(
        "serve-parity-m1",
        1,
        4,
        1e4,
        AShift::Range(0.1, 0.4),
        2.0,
        CommModel::Stochastic,
        31,
    );
    let jobs = 40;
    let seed = 2024;
    let mut c = cfg("markov");
    c.jobs = jobs;
    c.seed = seed;
    c.load_factor = 4.0; // deep overload: the queue is exercised
    let out = serve::run(&s, &c).unwrap();
    assert_eq!(out.records.len(), jobs);
    assert_eq!(out.infeasible, 0);

    let plan = policy("markov").build(&s).unwrap();
    // The serving cold plan IS the batch plan.
    assert_eq!(out.cold_plan, plan);
    let mc = sim::run(
        &s,
        &plan,
        &McOptions {
            trials: jobs,
            seed,
            keep_samples: true,
            threads: 1, // one RNG stream = the serve service stream
            ziggurat: false,
        },
    );
    let samples = mc.samples.unwrap();
    for (j, r) in out.records.iter().enumerate() {
        assert_eq!(r.job, j);
        assert_eq!(
            r.service_ms, samples[j],
            "job {j}: serve service diverged from batch trial"
        );
        assert!(r.sojourn_ms() >= r.service_ms);
    }
    // Overload actually queued some jobs (so the pin covers waiting jobs).
    assert!(out.records.iter().any(|r| r.wait_ms() > 0.0));
}

/// Multi-master lockstep: deterministic arrivals with a period far above
/// any possible service keep all masters' admissions simultaneous, so
/// the serve draw order equals the batch trial loop's (trial-major,
/// masters in order) and every per-master record matches `sim::run`'s
/// per-master samples bit-for-bit.
#[test]
fn constant_share_serve_matches_batch_engine_bit_for_bit_multi_master() {
    let s = Scenario::small_scale(17, 2.0, CommModel::Stochastic);
    let jobs = 25;
    let seed = 555;
    let mut c = cfg("markov");
    c.jobs = jobs;
    c.seed = seed;
    // t_ref / 1e-6 ≈ 1e6 × the planner estimate: no sampled service can
    // reach the next arrival tick (draw magnitudes are bounded by the
    // RNG's 2⁻⁵³ resolution through -ln(u)/rate).
    c.load_factor = 1e-6;
    let out = serve::run(&s, &c).unwrap();
    assert_eq!(out.records.len(), 2 * jobs);
    let plan = policy("markov").build(&s).unwrap();
    let mc = sim::run(
        &s,
        &plan,
        &McOptions {
            trials: jobs,
            seed,
            keep_samples: true,
            threads: 1,
            ziggurat: false,
        },
    );
    let master_samples = mc.master_samples.unwrap();
    for r in &out.records {
        assert_eq!(
            r.service_ms, master_samples[r.master][r.job],
            "master {} job {}",
            r.master, r.job
        );
        assert_eq!(r.wait_ms(), 0.0, "lockstep run must never queue");
    }
}

/// Plan-cache hits must be indistinguishable from cold replans: the same
/// churn timeline with the cache disabled (every admission replans from
/// scratch) produces bit-identical records.
#[test]
fn plan_cache_hit_equals_cold_replan_bit_for_bit() {
    let s = Scenario::small_scale(9, 2.0, CommModel::Stochastic);
    // Script times in units of the run's own inter-arrival (period =
    // t*/load_factor, the same formula serve::run uses): admissions are
    // spread over ~30 periods, so each window sees several of them.
    let period = policy("markov").build(&s).unwrap().t_est() / 0.8;
    let script = ChurnScript {
        events: vec![
            ChurnEvent { at_ms: 2.3 * period, worker: 2, action: ChurnAction::Leave },
            ChurnEvent { at_ms: 8.6 * period, worker: 2, action: ChurnAction::Join },
            ChurnEvent { at_ms: 14.4 * period, worker: 4, action: ChurnAction::Throttle(0.5) },
            ChurnEvent { at_ms: 21.9 * period, worker: 4, action: ChurnAction::Join },
        ],
    };
    let mut cached = cfg("markov");
    cached.jobs = 30;
    cached.script = Some(script.clone());
    cached.warm_start = false; // cold replans must be pure state functions
    let mut uncached = cached.clone();
    uncached.use_cache = false;
    let a = serve::run(&s, &cached).unwrap();
    let b = serve::run(&s, &uncached).unwrap();
    assert_eq!(a.records, b.records, "cache changed serving behavior");
    assert!(a.cache_hits > 0, "cache never hit");
    assert_eq!(b.cache_hits, 0);
    assert!(
        a.replans < b.replans,
        "cache did not reduce replans ({} vs {})",
        a.replans,
        b.replans
    );
    // The churn timeline actually produced distinct fleet states.
    assert!(a.replans >= 2, "script never changed the planning state");
    assert!(a.records.iter().any(|r| r.epoch > 0));
}

/// Jobs arriving while a worker is away are planned without it; a job in
/// service when its workers leave forever starves and is recorded
/// `feasible: false` with an explicit null sojourn in JSON.
#[test]
fn jobs_during_and_across_churn() {
    let s = Scenario::random(
        "serve-churn-m1",
        1,
        2,
        1e4,
        AShift::Range(0.2, 0.3),
        2.0,
        CommModel::Stochastic,
        77,
    );
    // Both workers leave almost immediately and never return: the first
    // job (admitted at t = 0 with the full fleet) starves mid-service —
    // its local link alone cannot reach L.
    let gone = ChurnScript {
        events: vec![
            ChurnEvent { at_ms: 1e-6, worker: 1, action: ChurnAction::Leave },
            ChurnEvent { at_ms: 1e-6, worker: 2, action: ChurnAction::Leave },
        ],
    };
    let mut c = cfg("markov");
    c.jobs = 1;
    c.script = Some(gone);
    let out = serve::run(&s, &c).unwrap();
    assert_eq!(out.records.len(), 1);
    assert_eq!(out.infeasible, 1);
    let r = &out.records[0];
    assert!(!r.feasible());
    assert!(r.service_ms.is_infinite());
    let j = r.to_json();
    assert_eq!(
        j.get("sojourn_ms"),
        Some(&coded_coop::util::json::Json::Null)
    );
    assert_eq!(
        j.get("feasible").and_then(coded_coop::util::json::Json::as_bool),
        Some(false)
    );
    assert_eq!(out.system.count(), 0, "starved jobs stay out of the summary");

    // Worker 1 leaves between job 0's completion and job 1's arrival:
    // jobs 1.. arrive while it is away, get planned without it, and
    // still complete (local + worker 2 carry 2L of coded load).
    let period = policy("markov").build(&s).unwrap().t_est() * 1e6;
    let away = ChurnScript {
        events: vec![ChurnEvent {
            at_ms: 0.5 * period, // far past job 0's bounded service
            worker: 1,
            action: ChurnAction::Leave,
        }],
    };
    let mut c = cfg("markov");
    c.jobs = 4;
    c.load_factor = 1e-6; // spaced arrivals: jobs 1.. admitted while away
    c.script = Some(away);
    let out = serve::run(&s, &c).unwrap();
    assert_eq!(out.infeasible, 0, "{:?}", out.records);
    // The full-fleet plan is pre-seeded; only the away state replans.
    assert_eq!(out.replans, 1, "exactly one away replan");
    assert_eq!(out.records[0].epoch, 0);
    assert!(out.records[0].cache_hit, "job 0 reuses the pre-seeded plan");
    assert!(out.records.iter().skip(1).all(|r| r.epoch == 1));
}

/// Mid-service throttling of every worker strictly stretches service
/// relative to the identical unchurned run (same seed, same draws).
#[test]
fn mid_service_throttle_stretches_service() {
    let s = Scenario::small_scale(13, 2.0, CommModel::Stochastic);
    let mut base = cfg("markov");
    base.jobs = 5;
    base.load_factor = 1e-6;
    let plain = serve::run(&s, &base).unwrap();
    let mut churned = base.clone();
    churned.script = Some(ChurnScript {
        events: (1..=s.n_workers())
            .map(|w| ChurnEvent {
                at_ms: 1e-6,
                worker: w,
                action: ChurnAction::Throttle(0.01),
            })
            .collect(),
    });
    let slow = serve::run(&s, &churned).unwrap();
    // Job 0 of each master is admitted at t = 0 (pre-throttle plan and
    // draws identical), then every worker slows 100×: its service must
    // strictly exceed the unchurned run's.
    for m in 0..s.n_masters() {
        let p = plain
            .records
            .iter()
            .find(|r| r.master == m && r.job == 0)
            .unwrap();
        let q = slow
            .records
            .iter()
            .find(|r| r.master == m && r.job == 0)
            .unwrap();
        assert!(q.service_ms.is_finite());
        assert!(
            q.service_ms > p.service_ms,
            "master {m}: throttle did not stretch ({} vs {})",
            q.service_ms,
            p.service_ms
        );
    }
}

/// Warm-started SCA serving matches cold serving's quality while
/// spending no more subproblem solves.
#[test]
fn warm_start_serving_matches_cold_quality() {
    let s = Scenario::small_scale(21, 2.0, CommModel::Stochastic);
    let mut warm = cfg("sca");
    warm.jobs = 12;
    warm.churn_rate = 1.0;
    warm.use_cache = false; // replan every admission: maximal SCA load
    let mut cold = warm.clone();
    cold.warm_start = false;
    let w = serve::run(&s, &warm).unwrap();
    let c = serve::run(&s, &cold).unwrap();
    assert_eq!(w.records.len(), c.records.len());
    assert!(w.sca_iters > 0 && c.sca_iters > 0);
    assert!(
        w.sca_iters <= c.sca_iters,
        "warm starts cost more subproblem solves ({} vs {})",
        w.sca_iters,
        c.sca_iters
    );
    // Same stationary points ⇒ near-identical serving behavior.
    for (x, y) in w.records.iter().zip(&c.records) {
        assert_eq!(x.feasible(), y.feasible());
        if x.feasible() {
            // Stationary points agree to ~1e-3 in loads; the sampled
            // delays inherit that scale, so allow a few percent.
            let rel = (x.sojourn_ms() - y.sojourn_ms()).abs() / y.sojourn_ms().max(1e-9);
            assert!(rel < 0.05, "sojourn diverged: {} vs {}", x.sojourn_ms(), y.sojourn_ms());
        }
    }
}

/// The `serving` catalog sweep runs end-to-end through the same entry
/// the CLI uses, deterministically.
#[test]
fn serving_catalog_sweep_end_to_end() {
    let spec = catalog::spec("serving", 6, 5).unwrap();
    let a = experiment::run_serving_with(&spec, |_| {}).unwrap();
    assert_eq!(a.cells.len(), 18);
    for c in &a.cells {
        assert_eq!(c.outcome.executor, "serve");
        assert_eq!(c.records.len(), 2 * 6); // M = 2 × 6 jobs
        assert!(c.outcome.system.count() > 0, "cell {} served nothing", c.index);
        assert!(c.outcome.samples.as_ref().is_some_and(|s| !s.is_empty()));
    }
    let b = experiment::run_serving_with(&spec, |_| {}).unwrap();
    for (x, y) in a.cells.iter().zip(&b.cells) {
        assert_eq!(x.records, y.records, "serving sweep not deterministic");
    }
    // Churned columns replanned; static columns did not.
    let static_cells: Vec<_> = a
        .cells
        .iter()
        .filter(|c| c.axis_values.iter().any(|(k, v)| k == "churn_rate" && *v == 0.0))
        .collect();
    assert!(!static_cells.is_empty());
    // Poisson processes exercise different arrival draws per master.
    let r = &a.cells[0].records;
    assert!(r.iter().filter(|x| x.master == 0).map(|x| x.arrival_ms).ne(r
        .iter()
        .filter(|x| x.master == 1)
        .map(|x| x.arrival_ms)));
}

/// The acceptance pin: the `serving` catalog (which runs through the
/// timer wheel by default) reproduces bit-for-bit what the binary-heap
/// oracle produces for the same cell configurations.
#[test]
fn serving_catalog_reproduces_bit_for_bit_through_the_wheel() {
    let spec = catalog::spec("serving", 6, 5).unwrap();
    let wheel = experiment::run_serving_with(&spec, |_| {}).unwrap();
    let cells = spec.expand().unwrap();
    assert_eq!(wheel.cells.len(), cells.len());
    for (cell, wc) in cells.into_iter().zip(&wheel.cells) {
        // Rebuild the cell's ServeConfig exactly as the sweep layer
        // does, but force the heap oracle.
        let arr = cell.arrivals.as_ref().unwrap();
        let mut c = ServeConfig::new(cell.policy.clone());
        c.process = arr.process;
        c.load_factor = arr.load_factor;
        c.jobs = arr.jobs;
        c.churn_rate = arr.churn_rate;
        c.churn_downtime = arr.churn_downtime;
        c.record_cap = arr.record_cap;
        c.seed = cell.seed;
        c.queue = EventQueueKind::Heap;
        let heap = serve::run(&cell.scenario, &c).unwrap();
        assert_eq!(
            wc.records, heap.records,
            "cell {}: wheel diverged from the heap oracle",
            cell.index
        );
        assert_eq!(wc.outcome.system.mean().to_bits(), heap.system.mean().to_bits());
        assert_eq!(wc.p99_ms, heap.p99_ms(), "cell {}: sketch p99 diverged", cell.index);
    }
}

/// Sharded serving on the process pool reproduces the sequential
/// per-master-stream run: per-master records and summaries are
/// bit-identical, totals agree.
#[test]
fn sharded_serving_matches_sequential_on_the_pool() {
    let s = Scenario::small_scale(17, 2.0, CommModel::Stochastic);
    let mut c = cfg("markov");
    c.jobs = 30;
    c.load_factor = 1.5;
    c.process = serve::ArrivalProcess::Burst;
    c.churn_rate = 1.0;
    c.streams = ServiceStreams::PerMaster;
    let seq = serve::run(&s, &c).unwrap();
    let shard = serve::run_sharded(&s, &c).unwrap();
    assert_eq!(seq.jobs, shard.jobs);
    assert_eq!(seq.infeasible, shard.infeasible);
    for m in 0..s.n_masters() {
        let a: Vec<_> = seq.records.iter().filter(|r| r.master == m).collect();
        let b: Vec<_> = shard.records.iter().filter(|r| r.master == m).collect();
        assert_eq!(a, b, "master {m}: shard diverged from sequential");
        assert_eq!(
            seq.per_master[m].mean().to_bits(),
            shard.per_master[m].mean().to_bits(),
            "master {m}: summary not bit-identical"
        );
        assert_eq!(seq.p99_master_ms(m), shard.p99_master_ms(m));
    }
}

/// The `overload` catalog sweep end-to-end: every cell past saturation,
/// burst arrivals, records bounded by the ring while the job counters
/// and sketch tails cover everything.
#[test]
fn overload_catalog_sweep_end_to_end() {
    let spec = catalog::spec("overload", 600, 5).unwrap();
    let out = experiment::run_serving_with(&spec, |_| {}).unwrap();
    assert_eq!(out.cells.len(), 6);
    for c in &out.cells {
        assert_eq!(c.outcome.executor, "serve");
        assert_eq!(c.jobs, 2 * 600, "counters must be cap-independent");
        assert!(
            c.records.len() <= catalog::OVERLOAD_RECORD_CAP,
            "cell {}: ring exceeded the cap",
            c.index
        );
        assert!(c.p99_ms.is_some(), "cell {}: no sketch tail", c.index);
        assert!(
            c.p99_ms.unwrap() >= c.outcome.system.mean(),
            "cell {}: p99 below the mean",
            c.index
        );
    }
    // Heavier overload ⇒ no smaller mean sojourn (same policy column).
    for pol in 0..2 {
        let lo = &out.cells[pol];
        let hi = &out.cells[4 + pol];
        assert!(
            hi.outcome.system.mean() >= lo.outcome.system.mean(),
            "policy {pol}: 4.0× load served faster than 1.5×"
        );
    }
}
