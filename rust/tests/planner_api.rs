//! Integration tests for the open planner API: registry completeness,
//! schema-versioned JSON round-trips (property-tested), executor
//! equivalence, and the "add a policy with zero core edits" acceptance
//! check.

use std::sync::Arc;

use coded_coop::alloc::Allocation;
use coded_coop::assign::ValueModel;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::exec::{
    executor_by_name, CoordinatorExecutor, ExecOptions, Executor, SimExecutor,
};
use coded_coop::figures::{common, FigureOptions};
use coded_coop::plan::{self, LoadMethod, Plan, PlanSpec, Policy};
use coded_coop::policy::{registry, Assigner, Assignment, LoadAllocator, PolicySpec};
use coded_coop::sim::{self, McOptions};
use coded_coop::util::json::{self, Json};
use coded_coop::util::prop::{check, Config};

const BUILTIN_POLICIES: &[&str] =
    &["uncoded", "coded", "dedi-simple", "dedi-iter", "frac", "optimal"];
const BUILTIN_LOADS: &[&str] = &["markov", "exact", "sca"];

#[test]
fn registry_resolves_every_builtin_policy_name() {
    for &policy in BUILTIN_POLICIES {
        for &loads in BUILTIN_LOADS {
            let spec = PolicySpec::new(policy, ValueModel::Markov, loads);
            let r = spec
                .resolve()
                .unwrap_or_else(|e| panic!("{policy}/{loads}: {e}"));
            assert!(!r.label().is_empty());
        }
    }
    let names = registry::assigner_names();
    for &p in BUILTIN_POLICIES {
        assert!(names.iter().any(|n| n == p), "registry missing {p}");
    }
    let names = registry::allocator_names();
    for &l in BUILTIN_LOADS {
        assert!(names.iter().any(|n| n == l), "registry missing {l}");
    }
}

#[test]
fn legacy_plan_spec_builds_identically_to_policy_spec() {
    let s = Scenario::small_scale(3, 2.0, CommModel::Stochastic);
    for (policy, name) in [
        (Policy::UncodedUniform, "uncoded"),
        (Policy::CodedUniform, "coded"),
        (Policy::DediSimple, "dedi-simple"),
        (Policy::DediIter, "dedi-iter"),
        (Policy::Frac, "frac"),
    ] {
        let legacy = plan::build(
            &s,
            &PlanSpec {
                policy,
                values: ValueModel::Markov,
                loads: LoadMethod::Markov,
            },
        );
        let open = PolicySpec::new(name, ValueModel::Markov, "markov")
            .build(&s)
            .unwrap();
        assert_eq!(legacy, open, "{name}");
    }
}

#[test]
fn prop_plan_and_spec_json_roundtrip() {
    check(
        Config::default().cases(20),
        "Plan/PolicySpec JSON round-trip over random scenarios",
        |g| {
            let m = g.usize_range(1, 3);
            let n = g.usize_range(m.max(2), 10);
            let seed = g.rng().next_u64();
            let s = Scenario::random(
                "prop-roundtrip",
                m,
                n,
                1e3,
                AShift::Range(0.05, 0.5),
                2.0,
                CommModel::Stochastic,
                seed,
            );
            let policy = *g
                .rng()
                .choose(&["uncoded", "coded", "dedi-simple", "dedi-iter", "frac"]);
            let loads = *g.rng().choose(&["markov", "sca"]);
            let spec = PolicySpec::new(policy, ValueModel::Markov, loads);
            let p = spec.build(&s).unwrap();
            let text = p.to_json().to_string_pretty();
            let back = Plan::from_json(&json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, p, "{policy}/{loads}");
            assert_eq!(back.t_est(), p.t_est());
            let spec_back = PolicySpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec_back, spec);
        },
    );
}

#[test]
fn exported_plan_reproduces_direct_results() {
    // The `plan export` → `plan run` acceptance: the serialized document
    // reproduces the direct path's t_est and simulated system delay
    // EXACTLY (same plan bits, same seed).
    let s = Scenario::small_scale(11, 2.0, CommModel::Stochastic);
    let spec = PolicySpec::new("dedi-iter", ValueModel::Markov, "sca");
    let plan_direct = spec.build(&s).unwrap();

    let mut doc = Json::obj();
    doc.set("schema", Json::Num(Plan::SCHEMA as f64));
    doc.set("spec", spec.to_json());
    doc.set("scenario", s.to_json());
    doc.set("plan", plan_direct.to_json());
    let text = doc.to_string_pretty();

    let parsed = json::parse(&text).unwrap();
    let s_back = Scenario::from_json(parsed.get("scenario").unwrap()).unwrap();
    let plan_back = Plan::from_json(parsed.get("plan").unwrap()).unwrap();
    let spec_back = PolicySpec::from_json(parsed.get("spec").unwrap()).unwrap();

    assert_eq!(spec_back, spec);
    assert_eq!(plan_back.t_est(), plan_direct.t_est());
    let mc = McOptions {
        trials: 4_000,
        seed: 9,
        keep_samples: false,
        threads: 2,
        ziggurat: false,
    };
    let direct = sim::run(&s, &plan_direct, &mc);
    let roundtrip = sim::run(&s_back, &plan_back, &mc);
    assert_eq!(direct.system.mean(), roundtrip.system.mean());
    assert_eq!(direct.system.count(), roundtrip.system.count());
}

#[test]
fn sim_and_coordinator_executors_agree_on_plan_invariants() {
    let s = Scenario::random(
        "exec-equiv",
        2,
        5,
        192.0,
        AShift::Range(0.01, 0.05),
        2.0,
        CommModel::Stochastic,
        23,
    );
    let plan = PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")
        .build(&s)
        .unwrap();
    // Coded plans carry redundancy: Σ l_{m,n} ≥ L_m for every master.
    for mp in &plan.masters {
        assert!(
            mp.total_load() >= mp.l_rows,
            "Σl = {} < L = {}",
            mp.total_load(),
            mp.l_rows
        );
    }
    let opts = ExecOptions {
        trials: 2_000,
        seed: 3,
        cols: 16,
        time_scale: 1e-6,
        verify: true,
        ..Default::default()
    };
    let sim_out = SimExecutor.execute(&s, &plan, &opts).unwrap();
    let coord_out = CoordinatorExecutor::default()
        .execute(&s, &plan, &opts)
        .unwrap();
    // One plan, one label, one t_est — whichever engine runs it.
    assert_eq!(sim_out.label, coord_out.label);
    assert_eq!(sim_out.t_est_ms, coord_out.t_est_ms);
    assert_eq!(sim_out.per_master.len(), coord_out.per_master.len());
    assert_eq!(sim_out.system.count() as usize, opts.trials);
    assert_eq!(coord_out.system.count(), 1);
    assert!(sim_out.system_mean_ms() > 0.0);
    assert!(coord_out.system_mean_ms().is_finite() && coord_out.system_mean_ms() > 0.0);
    // And by name, as the CLI resolves them.
    assert_eq!(executor_by_name("sim").unwrap().name(), "sim");
    assert_eq!(
        executor_by_name("coordinator").unwrap().name(),
        "coordinator"
    );
}

/// Acceptance check: a brand-new policy goes registry name → CLI-style
/// resolution → figure harness by implementing the two traits in ONE
/// place, with zero edits to `plan::build` (which no longer has policy
/// match arms at all).
#[test]
fn toy_policy_registers_end_to_end() {
    struct RoundRobin;
    impl Assigner for RoundRobin {
        fn label(&self) -> String {
            "Toy, round-robin".into()
        }
        fn assign(&self, s: &Scenario) -> Assignment {
            Assignment::Dedicated {
                d: coded_coop::assign::Dedicated {
                    owner: (0..s.n_workers()).map(|w| w % s.n_masters()).collect(),
                },
                include_local: true,
                uncoded: false,
            }
        }
    }

    struct DoubleSplit;
    impl LoadAllocator for DoubleSplit {
        fn label_suffix(&self) -> &'static str {
            " + 2×split"
        }
        fn allocate(
            &self,
            s: &Scenario,
            m: usize,
            nodes: &[usize],
            _shares: &[(f64, f64)],
        ) -> Allocation {
            // 2× redundancy split equally; delay estimate = slowest mean.
            let per = 2.0 * s.l_rows(m) / nodes.len() as f64;
            let t_star = nodes
                .iter()
                .map(|&n| per * s.link(m, n).theta())
                .fold(0.0, f64::max);
            Allocation {
                loads: vec![per; nodes.len()],
                t_star,
            }
        }
    }

    registry::register_assigner("toy-rr", |_| Arc::new(RoundRobin) as Arc<dyn Assigner>);
    registry::register_allocator("toy-loads", || {
        Arc::new(DoubleSplit) as Arc<dyn LoadAllocator>
    });

    // Same resolution path as `coded-coop plan --policy toy-rr --loads toy-loads`.
    let spec = PolicySpec::new("toy-rr", ValueModel::Markov, "toy-loads");
    assert_eq!(spec.label().unwrap(), "Toy, round-robin + 2×split");
    assert!(registry::assigner_names().iter().any(|n| n == "toy-rr"));

    // Figure-harness style evaluation (the roster path).
    let s = Scenario::small_scale(2, 2.0, CommModel::Stochastic);
    let ev = common::evaluate(
        &s,
        &spec,
        &FigureOptions {
            trials: 500,
            seed: 1,
            fit_samples: 100,
            threads: 0,
        },
        false,
    );
    assert_eq!(ev.label, "Toy, round-robin + 2×split");
    assert!(ev.results.system.mean().is_finite() && ev.results.system.mean() > 0.0);
    // Round-robin placed every worker exactly once, 2× redundancy held.
    let mut seen = std::collections::HashSet::new();
    for mp in &ev.plan.masters {
        assert!((mp.total_load() - 2.0 * mp.l_rows).abs() < 1e-6);
        for e in &mp.entries {
            if e.node >= 1 {
                assert!(seen.insert(e.node));
            }
        }
    }
    assert_eq!(seen.len(), s.n_workers());

    // The serialized spec names the toy policy and still resolves.
    let back = PolicySpec::from_json(&spec.to_json()).unwrap();
    assert_eq!(back, spec);
    assert!(back.build(&s).is_ok());
}
