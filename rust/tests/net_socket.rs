//! Socket-mode integration: the TCP transport against in-process
//! loopback workers, pinned to the thread transport by a parity
//! contract, plus fuzz-ish codec properties.
//!
//! Workers here are real [`WorkerServer`]s on `127.0.0.1:0` served from
//! detached threads — the full wire protocol (handshake, assignment,
//! start barrier, Cancel frames, drain stats) without process spawning,
//! which `cargo test` cannot rely on (the test binary is not the CLI;
//! the auto-spawn path is exercised by the CI smoke job instead).

use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::worker::Outcome;
use coded_coop::coordinator::{
    run_plan, run_stream, Backend, RunOptions, StreamOptions, Transport,
};
use coded_coop::net::messages::{CodecError, Message, WireEvent};
use coded_coop::net::{frame, WorkerConfig, WorkerServer};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::util::prop::{check, Config, Gen};

/// Launch `n` loopback worker servers on OS-assigned ports, each
/// serving connections forever from a detached thread; returns their
/// addresses. Threads die with the test process.
fn loopback_workers(n: usize) -> Vec<String> {
    (0..n)
        .map(|_| {
            let server = WorkerServer::bind("127.0.0.1:0").expect("bind loopback");
            let addr = server.local_addr().expect("local addr").to_string();
            std::thread::spawn(move || {
                let _ = server.run(&WorkerConfig::default());
            });
            addr
        })
        .collect()
}

fn scenario(
    name: &str,
    masters: usize,
    workers: usize,
    l: f64,
    spread: f64,
    seed: u64,
) -> Scenario {
    Scenario::random(
        name,
        masters,
        workers,
        l,
        AShift::Range(0.01, spread),
        2.0,
        CommModel::Stochastic,
        seed,
    )
}

fn spec() -> PlanSpec {
    PlanSpec {
        policy: Policy::DediIter,
        values: coded_coop::assign::ValueModel::Markov,
        loads: LoadMethod::Markov,
    }
}

fn opts(seed: u64, transport: Transport) -> RunOptions {
    RunOptions {
        cols: 16,
        time_scale: 2e-5,
        backend: Backend::Native,
        seed,
        verify: true,
        transport,
        fault: None,
        health: coded_coop::health::HealthConfig::default(),
    }
}

/// The sub-task assignment a run actually executed, as a sorted
/// multiset of (worker, master, rows, deadline-bits). Outcomes are
/// excluded: whether a given sub-task computed or was cancelled is a
/// wall-clock race; WHAT was assigned WHERE with WHICH deadline is
/// deterministic (sampled coordinator-side from the seeded RNG).
type AssignmentKey = (usize, usize, usize, u64);

fn assignment(events: &[coded_coop::coordinator::worker::TaskEvent]) -> Vec<AssignmentKey> {
    let mut v: Vec<_> = events
        .iter()
        .map(|e| (e.worker, e.master, e.rows, e.deadline_ms.to_bits()))
        .collect();
    v.sort();
    v
}

#[test]
fn tcp_parity_with_thread_transport() {
    // Same seed, same plan, both transports: identical decoded products
    // (within verify tolerance) and identical sub-task assignment.
    let s = scenario("net-parity", 2, 4, 64.0, 0.05, 11);
    let p = plan::build(&s, &spec());

    let thread_report = run_plan(&s, &p, &opts(11, Transport::Thread)).unwrap();
    // 3 worker processes for 6 queues (2 local + 4 remote): round-robin,
    // each connection is one logical worker.
    let tcp_report = run_plan(&s, &p, &opts(11, Transport::tcp(loopback_workers(3)))).unwrap();

    assert!(thread_report.all_verified(1e-3), "{thread_report:?}");
    assert!(tcp_report.all_verified(1e-3), "{tcp_report:?}");
    assert_eq!(
        assignment(&thread_report.events),
        assignment(&tcp_report.events),
        "transports executed different sub-task assignments"
    );
    assert_eq!(thread_report.masters.len(), tcp_report.masters.len());
    for (t, n) in thread_report.masters.iter().zip(&tcp_report.masters) {
        // Both complete, so decode consumed exactly L rows each.
        assert_eq!(t.rows_used, n.rows_used);
        assert!(n.completion_ms.is_finite());
    }
}

#[test]
fn cancel_frames_stop_remaining_workers() {
    // Wide node-speed spread + near-real-time scale: fast workers
    // complete each master's L rows while slow deadlines are still
    // pending, so Cancel frames must reach workers mid-run. Asserted
    // via the worker-side TaskEvent logs that travel back in Shutdown.
    let s = scenario("net-cancel", 2, 10, 256.0, 0.2, 2);
    let p = plan::build(&s, &spec());
    let mut o = opts(2, Transport::tcp(loopback_workers(4)));
    o.time_scale = 2e-3;
    let report = run_plan(&s, &p, &o).unwrap();

    assert!(report.all_verified(1e-3), "{report:?}");
    let skipped: usize = report.worker_skipped.iter().sum();
    let cancelled_events = report
        .events
        .iter()
        .filter(|e| e.outcome == Outcome::Cancelled)
        .count();
    let cancelled_rows: usize = report.masters.iter().map(|m| m.rows_cancelled).sum();
    assert!(
        skipped > 0 || cancelled_events > 0 || cancelled_rows > 0,
        "no redundancy was cancelled over the wire: {report:?}"
    );
    // The drain stats from worker Shutdowns are coherent with the logs.
    assert_eq!(skipped, cancelled_events);
}

#[test]
fn stream_runs_over_tcp() {
    let s = scenario("net-stream", 2, 4, 64.0, 0.05, 11);
    let p = plan::build(&s, &spec());
    let outs = run_stream(
        &s,
        &p,
        &StreamOptions {
            jobs: 2,
            period_ms: 5.0,
            cols: 8,
            time_scale: 2e-5,
            backend: Backend::Native,
            seed: 11,
            verify: true,
            transport: Transport::tcp(loopback_workers(3)),
            fault: None,
            health: coded_coop::health::HealthConfig::default(),
        },
    )
    .unwrap();
    assert_eq!(outs.len(), 4);
    for o in &outs {
        assert!(o.completion_ms.is_finite(), "{o:?}");
        let err = o.max_rel_err.expect("verified");
        assert!(err < 1e-3, "job ({}, {}) decode error {err}", o.master, o.job);
    }
}

// ---- codec fuzz properties (satellite: random round-trips, typed ------
// truncation errors, no panics on garbage) ------------------------------

fn random_message(g: &mut Gen) -> Message {
    let small_vec = |g: &mut Gen, max: usize| {
        let len = g.usize_range(0, max);
        g.vec(len, |g| g.f64_range(-1e3, 1e3) as f32)
    };
    match g.usize_range(0, 5) {
        0 => Message::Hello {
            wid: g.usize_range(0, 1000) as u32,
            n_tasks: g.usize_range(0, 1000) as u32,
            n_cancel_slots: g.usize_range(0, 1000) as u32,
            time_scale: g.f64_range(0.0, 1.0),
            beat_ms: g.f64_range(0.0, 100.0),
        },
        1 => Message::TaskAssign {
            task: g.usize_range(0, 100) as u32,
            coded_start: g.usize_range(0, 10_000) as u32,
            rows: g.usize_range(0, 64) as u32,
            cols: g.usize_range(0, 64) as u32,
            delay_ms: g.f64_range(0.0, 1e4),
            a_block: small_vec(g, 256),
            x: small_vec(g, 64),
        },
        2 => Message::PartialResult {
            task: g.usize_range(0, 100) as u32,
            coded_start: g.usize_range(0, 10_000) as u32,
            rows: g.usize_range(0, 64) as u32,
            worker: g.usize_range(0, 100) as u32,
            delay_ms: g.f64_range(0.0, 1e4),
            values: small_vec(g, 256),
        },
        3 => Message::Cancel {
            task: g.usize_range(0, 1000) as u32,
        },
        4 => Message::Heartbeat {
            nonce: g.rng().next_u64(),
            rows_done: g.usize_range(0, 10_000) as u64,
            queue_depth: g.usize_range(0, 1000) as u32,
            last_latency_ms: g.f64_range(0.0, 1e3),
        },
        _ => Message::Shutdown {
            computed: g.usize_range(0, 1000) as u64,
            skipped: g.usize_range(0, 1000) as u64,
            disconnected: g.bool(),
            events: {
                let len = g.usize_range(0, 8);
                g.vec(len, |g| WireEvent {
                    worker: g.usize_range(0, 100) as u32,
                    task: g.usize_range(0, 100) as u32,
                    rows: g.usize_range(0, 1000) as u32,
                    deadline_ms: g.f64_range(0.0, 1e4),
                    compute_wall_ms: g.f64_range(0.0, 1e3),
                    outcome: match g.usize_range(0, 2) {
                        0 => Outcome::Computed,
                        1 => Outcome::Cancelled,
                        _ => Outcome::Failed,
                    },
                })
            },
        },
    }
}

#[test]
fn prop_random_messages_roundtrip() {
    check(Config::default().cases(300), "encode ∘ decode = id", |g| {
        let m = random_message(g);
        let bytes = m.encode();
        let back = Message::decode(&bytes).expect("decode own encoding");
        assert_eq!(m, back);
    });
}

#[test]
fn prop_truncations_are_typed_errors_never_panics() {
    check(
        Config::default().cases(100),
        "every strict prefix fails with a typed error",
        |g| {
            let bytes = random_message(g).encode();
            for cut in 0..bytes.len() {
                match Message::decode(&bytes[..cut]) {
                    Err(CodecError::Truncated { .. }) | Err(CodecError::Oversize { .. }) => {}
                    Err(e) => panic!("prefix {cut}/{}: unexpected error {e}", bytes.len()),
                    Ok(m) => panic!("prefix {cut}/{} decoded as {m:?}", bytes.len()),
                }
            }
        },
    );
}

#[test]
fn prop_garbage_bytes_never_panic() {
    check(Config::default().cases(300), "decode(garbage) is Err, not panic", |g| {
        let len = g.usize_range(0, 200);
        let bytes = g.vec(len, |g| g.rng().next_u64() as u8);
        // Any outcome but a panic is acceptable; a lucky decode must
        // re-encode to the same bytes it consumed.
        if let Ok(m) = Message::decode(&bytes) {
            assert_eq!(m.encode(), bytes);
        }
    });
}

#[test]
fn prop_framed_garbage_never_panics() {
    check(Config::default().cases(200), "read_frame(garbage) never panics", |g| {
        let len = g.usize_range(0, 64);
        let bytes = g.vec(len, |g| g.rng().next_u64() as u8);
        let mut cursor = std::io::Cursor::new(bytes);
        loop {
            match frame::read_frame(&mut cursor) {
                Ok(_) => continue,
                Err(_) => break, // typed Closed/Truncated/Oversize
            }
        }
    });
}
