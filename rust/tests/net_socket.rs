//! Socket-mode integration: the TCP transport against in-process
//! loopback workers, pinned to the thread transport by a parity
//! contract, plus fuzz-ish codec properties.
//!
//! Workers here are real [`WorkerServer`]s on `127.0.0.1:0` served from
//! detached threads — the full wire protocol (handshake, assignment,
//! start barrier, Cancel frames, drain stats) without process spawning,
//! which `cargo test` cannot rely on (the test binary is not the CLI;
//! the auto-spawn path is exercised by the CI smoke job instead).

use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::worker::Outcome;
use coded_coop::coordinator::{
    run_plan, run_stream, Backend, RunOptions, StreamOptions, TcpOptions, Transport,
};
use coded_coop::health::{FaultPlan, HealthConfig};
use coded_coop::net::messages::{
    auth_digest, ChunkAssembler, CodecError, Message, WireEvent, AUTH_LEN, NO_AUTH,
};
use coded_coop::net::worker::{RESUME_PARKED, RESUME_RUNNING};
use coded_coop::net::{frame, WorkerConfig, WorkerServer};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::util::prop::{check, Config, Gen};

/// Launch `n` loopback worker servers on OS-assigned ports, each
/// serving connections forever from a detached thread; returns their
/// addresses. Threads die with the test process.
fn loopback_workers(n: usize) -> Vec<String> {
    (0..n)
        .map(|_| {
            let server = WorkerServer::bind("127.0.0.1:0").expect("bind loopback");
            let addr = server.local_addr().expect("local addr").to_string();
            std::thread::spawn(move || {
                let _ = server.run(&WorkerConfig::default());
            });
            addr
        })
        .collect()
}

fn scenario(
    name: &str,
    masters: usize,
    workers: usize,
    l: f64,
    spread: f64,
    seed: u64,
) -> Scenario {
    Scenario::random(
        name,
        masters,
        workers,
        l,
        AShift::Range(0.01, spread),
        2.0,
        CommModel::Stochastic,
        seed,
    )
}

fn spec() -> PlanSpec {
    PlanSpec {
        policy: Policy::DediIter,
        values: coded_coop::assign::ValueModel::Markov,
        loads: LoadMethod::Markov,
    }
}

fn opts(seed: u64, transport: Transport) -> RunOptions {
    RunOptions {
        cols: 16,
        time_scale: 2e-5,
        backend: Backend::Native,
        seed,
        verify: true,
        transport,
        fault: None,
        health: coded_coop::health::HealthConfig::default(),
    }
}

/// The sub-task assignment a run actually executed, as a sorted
/// multiset of (worker, master, rows, deadline-bits). Outcomes are
/// excluded: whether a given sub-task computed or was cancelled is a
/// wall-clock race; WHAT was assigned WHERE with WHICH deadline is
/// deterministic (sampled coordinator-side from the seeded RNG).
type AssignmentKey = (usize, usize, usize, u64);

fn assignment(events: &[coded_coop::coordinator::worker::TaskEvent]) -> Vec<AssignmentKey> {
    let mut v: Vec<_> = events
        .iter()
        .map(|e| (e.worker, e.master, e.rows, e.deadline_ms.to_bits()))
        .collect();
    v.sort();
    v
}

#[test]
fn tcp_parity_with_thread_transport() {
    // Same seed, same plan, both transports: identical decoded products
    // (within verify tolerance) and identical sub-task assignment.
    let s = scenario("net-parity", 2, 4, 64.0, 0.05, 11);
    let p = plan::build(&s, &spec());

    let thread_report = run_plan(&s, &p, &opts(11, Transport::Thread)).unwrap();
    // 3 worker processes for 6 queues (2 local + 4 remote): round-robin,
    // each connection is one logical worker.
    let tcp_report = run_plan(&s, &p, &opts(11, Transport::tcp(loopback_workers(3)))).unwrap();

    assert!(thread_report.all_verified(1e-3), "{thread_report:?}");
    assert!(tcp_report.all_verified(1e-3), "{tcp_report:?}");
    assert_eq!(
        assignment(&thread_report.events),
        assignment(&tcp_report.events),
        "transports executed different sub-task assignments"
    );
    assert_eq!(thread_report.masters.len(), tcp_report.masters.len());
    for (t, n) in thread_report.masters.iter().zip(&tcp_report.masters) {
        // Both complete, so decode consumed exactly L rows each.
        assert_eq!(t.rows_used, n.rows_used);
        assert!(n.completion_ms.is_finite());
    }
}

#[test]
fn cancel_frames_stop_remaining_workers() {
    // Wide node-speed spread + near-real-time scale: fast workers
    // complete each master's L rows while slow deadlines are still
    // pending, so Cancel frames must reach workers mid-run. Asserted
    // via the worker-side TaskEvent logs that travel back in Shutdown.
    let s = scenario("net-cancel", 2, 10, 256.0, 0.2, 2);
    let p = plan::build(&s, &spec());
    let mut o = opts(2, Transport::tcp(loopback_workers(4)));
    o.time_scale = 2e-3;
    let report = run_plan(&s, &p, &o).unwrap();

    assert!(report.all_verified(1e-3), "{report:?}");
    let skipped: usize = report.worker_skipped.iter().sum();
    let cancelled_events = report
        .events
        .iter()
        .filter(|e| e.outcome == Outcome::Cancelled)
        .count();
    let cancelled_rows: usize = report.masters.iter().map(|m| m.rows_cancelled).sum();
    assert!(
        skipped > 0 || cancelled_events > 0 || cancelled_rows > 0,
        "no redundancy was cancelled over the wire: {report:?}"
    );
    // The drain stats from worker Shutdowns are coherent with the logs.
    assert_eq!(skipped, cancelled_events);
}

#[test]
fn stream_runs_over_tcp() {
    let s = scenario("net-stream", 2, 4, 64.0, 0.05, 11);
    let p = plan::build(&s, &spec());
    let outs = run_stream(
        &s,
        &p,
        &StreamOptions {
            jobs: 2,
            period_ms: 5.0,
            cols: 8,
            time_scale: 2e-5,
            backend: Backend::Native,
            seed: 11,
            verify: true,
            transport: Transport::tcp(loopback_workers(3)),
            fault: None,
            health: coded_coop::health::HealthConfig::default(),
        },
    )
    .unwrap();
    assert_eq!(outs.len(), 4);
    for o in &outs {
        assert!(o.completion_ms.is_finite(), "{o:?}");
        let err = o.max_rel_err.expect("verified");
        assert!(err < 1e-3, "job ({}, {}) decode error {err}", o.master, o.job);
    }
}

// ---- codec fuzz properties (satellite: random round-trips, typed ------
// truncation errors, no panics on garbage) ------------------------------

fn random_auth(g: &mut Gen) -> [u8; AUTH_LEN] {
    let mut a = [0u8; AUTH_LEN];
    for b in a.iter_mut() {
        *b = g.rng().next_u64() as u8;
    }
    a
}

fn random_message(g: &mut Gen) -> Message {
    let small_vec = |g: &mut Gen, max: usize| {
        let len = g.usize_range(0, max);
        g.vec(len, |g| g.f64_range(-1e3, 1e3) as f32)
    };
    match g.usize_range(0, 7) {
        0 => Message::Hello {
            wid: g.usize_range(0, 1000) as u32,
            n_tasks: g.usize_range(0, 1000) as u32,
            n_cancel_slots: g.usize_range(0, 1000) as u32,
            time_scale: g.f64_range(0.0, 1.0),
            beat_ms: g.f64_range(0.0, 100.0),
            session: g.rng().next_u64(),
            auth: random_auth(g),
        },
        1 => Message::TaskAssign {
            task: g.usize_range(0, 100) as u32,
            coded_start: g.usize_range(0, 10_000) as u32,
            rows: g.usize_range(0, 64) as u32,
            cols: g.usize_range(0, 64) as u32,
            delay_ms: g.f64_range(0.0, 1e4),
            a_block: small_vec(g, 256),
            x: small_vec(g, 64),
        },
        2 => Message::PartialResult {
            task: g.usize_range(0, 100) as u32,
            coded_start: g.usize_range(0, 10_000) as u32,
            rows: g.usize_range(0, 64) as u32,
            worker: g.usize_range(0, 100) as u32,
            delay_ms: g.f64_range(0.0, 1e4),
            values: small_vec(g, 256),
        },
        3 => Message::Cancel {
            task: g.usize_range(0, 1000) as u32,
        },
        4 => Message::Heartbeat {
            nonce: g.rng().next_u64(),
            rows_done: g.usize_range(0, 10_000) as u64,
            queue_depth: g.usize_range(0, 1000) as u32,
            last_latency_ms: g.f64_range(0.0, 1e3),
        },
        5 => Message::Shutdown {
            computed: g.usize_range(0, 1000) as u64,
            skipped: g.usize_range(0, 1000) as u64,
            disconnected: g.bool(),
            events: {
                let len = g.usize_range(0, 8);
                g.vec(len, |g| WireEvent {
                    worker: g.usize_range(0, 100) as u32,
                    task: g.usize_range(0, 100) as u32,
                    rows: g.usize_range(0, 1000) as u32,
                    deadline_ms: g.f64_range(0.0, 1e4),
                    compute_wall_ms: g.f64_range(0.0, 1e3),
                    outcome: match g.usize_range(0, 2) {
                        0 => Outcome::Computed,
                        1 => Outcome::Cancelled,
                        _ => Outcome::Failed,
                    },
                })
            },
        },
        6 => Message::Resume {
            session_id: g.rng().next_u64(),
            last_acked_row: g.rng().next_u64(),
            auth: random_auth(g),
        },
        _ => Message::TaskAssignChunk {
            seq: g.usize_range(0, 1000) as u32,
            of: g.usize_range(0, 1000) as u32,
            payload: {
                let len = g.usize_range(0, 256);
                g.vec(len, |g| g.rng().next_u64() as u8)
            },
        },
    }
}

#[test]
fn prop_random_messages_roundtrip() {
    check(Config::default().cases(300), "encode ∘ decode = id", |g| {
        let m = random_message(g);
        let bytes = m.encode();
        let back = Message::decode(&bytes).expect("decode own encoding");
        assert_eq!(m, back);
    });
}

#[test]
fn prop_truncations_are_typed_errors_never_panics() {
    check(
        Config::default().cases(100),
        "every strict prefix fails with a typed error",
        |g| {
            let bytes = random_message(g).encode();
            for cut in 0..bytes.len() {
                match Message::decode(&bytes[..cut]) {
                    Err(CodecError::Truncated { .. }) | Err(CodecError::Oversize { .. }) => {}
                    Err(e) => panic!("prefix {cut}/{}: unexpected error {e}", bytes.len()),
                    Ok(m) => panic!("prefix {cut}/{} decoded as {m:?}", bytes.len()),
                }
            }
        },
    );
}

#[test]
fn prop_garbage_bytes_never_panic() {
    check(Config::default().cases(300), "decode(garbage) is Err, not panic", |g| {
        let len = g.usize_range(0, 200);
        let bytes = g.vec(len, |g| g.rng().next_u64() as u8);
        // Any outcome but a panic is acceptable; a lucky decode must
        // re-encode to the same bytes it consumed.
        if let Ok(m) = Message::decode(&bytes) {
            assert_eq!(m.encode(), bytes);
        }
    });
}

#[test]
fn prop_framed_garbage_never_panics() {
    check(Config::default().cases(200), "read_frame(garbage) never panics", |g| {
        let len = g.usize_range(0, 64);
        let bytes = g.vec(len, |g| g.rng().next_u64() as u8);
        let mut cursor = std::io::Cursor::new(bytes);
        loop {
            match frame::read_frame(&mut cursor) {
                Ok(_) => continue,
                Err(_) => break, // typed Closed/Truncated/Oversize
            }
        }
    });
}

// ---- chunked-assign streaming (satellite: round-trip, strict ----------
// sequencing, total over garbage) ---------------------------------------

#[test]
fn prop_chunked_assign_roundtrips_bit_for_bit() {
    check(
        Config::default().cases(60),
        "send_chunked ∘ reassemble ∘ decode = id",
        |g| {
            let rows = g.usize_range(1, 24);
            let cols = g.usize_range(1, 24);
            let m = Message::TaskAssign {
                task: g.usize_range(0, 100) as u32,
                coded_start: g.usize_range(0, 10_000) as u32,
                rows: rows as u32,
                cols: cols as u32,
                delay_ms: g.f64_range(0.0, 1e4),
                a_block: g.vec(rows * cols, |g| g.f64_range(-1e3, 1e3) as f32),
                x: g.vec(cols, |g| g.f64_range(-1e3, 1e3) as f32),
            };
            let budget = g.usize_range(16, 512);
            let mut buf = Vec::new();
            frame::send_chunked(&mut buf, &m, budget).unwrap();
            let mut c = std::io::Cursor::new(buf);
            let mut asm = ChunkAssembler::new();
            loop {
                match frame::recv(&mut c).unwrap() {
                    Message::TaskAssignChunk { seq, of, payload } => {
                        assert!(payload.len() <= budget, "chunk exceeds budget");
                        if let Some(bytes) = asm.push(seq, of, &payload).unwrap() {
                            assert_eq!(bytes, m.encode(), "reassembly must be bit-for-bit");
                            assert_eq!(Message::decode(&bytes).unwrap(), m);
                            break;
                        }
                    }
                    // Encoding fit the budget: one plain frame, no chunks.
                    other => {
                        assert_eq!(other, m);
                        break;
                    }
                }
            }
            assert!(frame::recv(&mut c).unwrap_err().is_closed());
        },
    );
}

#[test]
fn prop_chunk_stream_mutations_are_typed_errors() {
    check(
        Config::default().cases(120),
        "gap/duplicate/reorder chunk streams reject with a typed error",
        |g| {
            let of = g.usize_range(2, 6) as u32;
            let mut seqs: Vec<u32> = (0..of).collect();
            match g.usize_range(0, 2) {
                0 => {
                    // Corrupt one seq (never equal to its original).
                    let i = g.usize_range(0, seqs.len() - 1);
                    seqs[i] = seqs[i].wrapping_add(1 + g.usize_range(0, 3) as u32);
                }
                1 => {
                    // Duplicate a delivered seq.
                    let i = g.usize_range(1, seqs.len() - 1);
                    seqs.insert(i, seqs[i - 1]);
                }
                _ => {
                    // Drop one seq; tail a bogus one so the stream still
                    // carries `of` pieces.
                    let i = g.usize_range(0, seqs.len() - 1);
                    seqs.remove(i);
                    seqs.push(of + 7);
                }
            }
            let mut asm = ChunkAssembler::new();
            let mut err = None;
            for &s in &seqs {
                match asm.push(s, of, b"xy") {
                    Ok(Some(_)) => panic!("mutated stream completed a reassembly"),
                    Ok(None) => {}
                    Err(e) => {
                        err = Some(e);
                        break;
                    }
                }
            }
            let e = err.expect("mutated stream must be rejected");
            assert!(
                matches!(
                    e,
                    CodecError::ChunkSequence { .. } | CodecError::ChunkCount { .. }
                ),
                "unexpected rejection {e:?}"
            );
            // Every rejection resets the assembler for a clean restart.
            assert!(!asm.in_progress());
        },
    );
}

#[test]
fn prop_chunk_assembler_is_total_over_garbage() {
    check(
        Config::default().cases(200),
        "assembler never panics on arbitrary (seq, of, payload)",
        |g| {
            let mut asm = ChunkAssembler::new();
            let n = g.usize_range(0, 20);
            for _ in 0..n {
                let seq = g.rng().next_u64() as u32;
                let of = g.rng().next_u64() as u32;
                let len = g.usize_range(0, 64);
                let payload = g.vec(len, |g| g.rng().next_u64() as u8);
                let _ = asm.push(seq, of, &payload); // Ok or Err, never panic
            }
        },
    );
}

// ---- auth handshake (satellite: wrong digest dropped silently, --------
// right token runs end-to-end) ------------------------------------------

#[test]
fn auth_gate_rejects_wrong_token_and_admits_the_right_one() {
    let token = "open sesame";
    let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let cfg = WorkerConfig {
        auth: Some(token.to_string()),
        ..WorkerConfig::default()
    };
    std::thread::spawn(move || {
        let _ = server.run(&cfg);
    });

    // A wrong digest is dropped without any reply frame.
    let stream = std::net::TcpStream::connect(&addr).expect("connect");
    let mut w = std::io::BufWriter::new(stream.try_clone().expect("clone"));
    let mut r = std::io::BufReader::new(stream);
    frame::send(
        &mut w,
        &Message::Hello {
            wid: 0,
            n_tasks: 0,
            n_cancel_slots: 0,
            time_scale: 1.0,
            beat_ms: 0.0,
            session: 0,
            auth: auth_digest("not the token"),
        },
    )
    .expect("send hello");
    match frame::recv(&mut r) {
        Err(e) => assert!(
            matches!(e, frame::WireError::Frame(_)),
            "expected a dropped connection, got {e:?}"
        ),
        Ok(m) => panic!("unauthenticated peer received a reply: {m:?}"),
    }

    // The all-zero NO_AUTH sentinel (an unconfigured coordinator) is
    // rejected the same way — zeros never satisfy a required token.
    let s = scenario("net-auth", 1, 3, 32.0, 0.05, 5);
    let p = plan::build(&s, &spec());
    let bad = opts(
        5,
        Transport::Tcp(TcpOptions {
            addrs: vec![addr.clone(); 2],
            auth: None,
        }),
    );
    assert!(
        run_plan(&s, &p, &bad).is_err(),
        "tokenless coordinator must not pass an auth-requiring worker"
    );

    // The right token handshakes and the run verifies end-to-end.
    let good = opts(
        5,
        Transport::Tcp(TcpOptions {
            addrs: vec![addr; 2],
            auth: Some(token.to_string()),
        }),
    );
    let report = run_plan(&s, &p, &good).expect("authenticated run");
    assert!(report.all_verified(1e-3), "{report:?}");
}

// ---- resumable sessions (tentpole: park on drop, replay past the ------
// acked watermark, e2e recovery) ----------------------------------------

/// Drive the worker protocol by hand: a resumable session whose socket
/// is severed before any result lands, then a `Resume` that must replay
/// exactly the parked results past the acked-row watermark.
#[test]
fn resume_replays_parked_results_past_the_watermark() {
    let fault = FaultPlan::parse("drop:w1@0%").expect("fault plan");
    let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
    let addr = server.local_addr().expect("addr").to_string();
    let cfg = WorkerConfig {
        fault: Some(fault),
        ..WorkerConfig::default()
    };
    std::thread::spawn(move || {
        let _ = server.run(&cfg);
    });

    const SESSION: u64 = 777;
    // Session 777: Hello, two assignments, start barrier. The drop
    // fault severs the socket at the first publish, so nothing arrives
    // on this connection — the worker computes on and parks.
    let stream = std::net::TcpStream::connect(&addr).expect("connect");
    let mut w = std::io::BufWriter::new(stream.try_clone().expect("clone"));
    let mut r = std::io::BufReader::new(stream);
    frame::send(
        &mut w,
        &Message::Hello {
            wid: 0,
            n_tasks: 2,
            n_cancel_slots: 2,
            time_scale: 1e-6,
            beat_ms: 0.0,
            session: SESSION,
            auth: NO_AUTH,
        },
    )
    .expect("hello");
    match frame::recv(&mut r).expect("hello ack") {
        Message::Hello { .. } => {}
        other => panic!("expected Hello ack, got {other:?}"),
    }
    // rows×cols = 2×2 against x = [1, 1]: task 0 → [3, 7], task 1 →
    // [11, 15] (exact in f32).
    for (task, a) in [(0u32, [1.0f32, 2.0, 3.0, 4.0]), (1, [5.0, 6.0, 7.0, 8.0])] {
        frame::send(
            &mut w,
            &Message::TaskAssign {
                task,
                coded_start: task * 2,
                rows: 2,
                cols: 2,
                delay_ms: 1.0 + task as f64,
                a_block: a.to_vec(),
                x: vec![1.0, 1.0],
            },
        )
        .expect("assign");
    }
    frame::send(
        &mut w,
        &Message::Heartbeat {
            nonce: 0,
            rows_done: 0,
            queue_depth: 0,
            last_latency_ms: 0.0,
        },
    )
    .expect("barrier");
    // The injected drop severs the stream; drain to the error.
    while frame::recv(&mut r).is_ok() {}

    // Resume with last_acked_row = 2: the worker published two 2-row
    // results, so exactly ONE (whichever published second) is past the
    // watermark — the acked prefix is never replayed, never recomputed.
    let mut parked = None;
    for _ in 0..400 {
        let stream = std::net::TcpStream::connect(&addr).expect("reconnect");
        let mut w = std::io::BufWriter::new(stream.try_clone().expect("clone"));
        let mut r = std::io::BufReader::new(stream);
        frame::send(
            &mut w,
            &Message::Resume {
                session_id: SESSION,
                last_acked_row: 2,
                auth: NO_AUTH,
            },
        )
        .expect("resume");
        match frame::recv(&mut r).expect("resume reply") {
            Message::Hello { n_cancel_slots, .. } if n_cancel_slots == RESUME_PARKED => {
                parked = Some((r, w));
                break;
            }
            // RUNNING (still computing) or MISS (registry insert not
            // reached yet — the barrier races the execute phase).
            Message::Hello { n_cancel_slots, .. } if n_cancel_slots == RESUME_RUNNING => {}
            Message::Hello { .. } => {}
            other => panic!("expected Hello reply, got {other:?}"),
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    let (mut r, mut w) = parked.expect("session never reached RESUME_PARKED");

    let mut results = Vec::new();
    let stats = loop {
        match frame::recv(&mut r).expect("replay stream") {
            Message::PartialResult {
                task, rows, values, ..
            } => results.push((task, rows, values)),
            Message::Shutdown {
                computed,
                skipped,
                events,
                ..
            } => break (computed, skipped, events),
            other => panic!("unexpected {other:?} in replay"),
        }
    };
    assert_eq!(
        results.len(),
        1,
        "watermark 2 must skip the first 2-row result: {results:?}"
    );
    let (task, rows, values) = &results[0];
    assert_eq!(*rows, 2);
    let want: &[f32] = if *task == 0 { &[3.0, 7.0] } else { &[11.0, 15.0] };
    assert_eq!(values.as_slice(), want, "replayed values for task {task}");
    // The parked drain stats travel with the replay.
    assert_eq!(stats.0, 2, "both tasks computed despite the drop");
    assert_eq!(stats.1, 0);
    assert_eq!(stats.2.len(), 2);
    // Release the resume connection.
    frame::send(
        &mut w,
        &Message::Shutdown {
            computed: 0,
            skipped: 0,
            disconnected: false,
            events: Vec::new(),
        },
    )
    .expect("release");
}

#[test]
fn tcp_drop_is_resumed_and_decodes() {
    // w1 (wid 0) severs its socket at the first publish but keeps
    // computing. The armed coordinator must observe the disconnect,
    // walk the Resume path (or re-queue on a miss) and still decode.
    let fault = FaultPlan::parse("drop:w1@0%").expect("fault plan");
    let s = scenario("net-drop", 2, 4, 64.0, 0.05, 11);
    let p = plan::build(&s, &spec());
    let addrs: Vec<String> = (0..4)
        .map(|_| {
            let server = WorkerServer::bind("127.0.0.1:0").expect("bind");
            let addr = server.local_addr().expect("addr").to_string();
            let cfg = WorkerConfig {
                fault: Some(fault.clone()),
                ..WorkerConfig::default()
            };
            std::thread::spawn(move || {
                let _ = server.run(&cfg);
            });
            addr
        })
        .collect();
    let mut o = opts(11, Transport::tcp(addrs));
    o.time_scale = 2e-3;
    let mut h = HealthConfig::fast();
    h.armed = true;
    o.health = h;
    let report = run_plan(&s, &p, &o).unwrap();

    assert!(report.all_verified(1e-3), "{report:?}");
    let kinds: Vec<&str> = report.health.iter().map(|e| e.kind_label()).collect();
    assert!(kinds.contains(&"disconnect"), "no disconnect logged: {kinds:?}");
    assert!(
        kinds.contains(&"reconnect") || kinds.contains(&"requeue"),
        "neither resumed nor re-queued: {kinds:?}"
    );
}
