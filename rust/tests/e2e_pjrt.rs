//! Integration over the PJRT runtime + coordinator: the real three-layer
//! path (HLO artifacts → runtime service → worker threads → decode).
//!
//! Requires `make artifacts` to have run (the Makefile `test` target
//! guarantees ordering).

use coded_coop::assign::ValueModel;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::{self, Backend, CoordinatorConfig};
use coded_coop::plan::{LoadMethod, PlanSpec, Policy};
use coded_coop::runtime::{artifacts_available, default_artifact_dir, RuntimeService};

/// `None` (⇒ the test skips) when `make artifacts` has not been run: the
/// artifact pipeline needs the Python L1/L2 toolchain, which the Rust
/// crate's CI does not assume.
fn service() -> Option<RuntimeService> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(
        RuntimeService::start(&default_artifact_dir())
            .expect("manifest present but runtime failed to start"),
    )
}

fn scenario(seed: u64, rows: f64) -> Scenario {
    Scenario::random(
        "e2e-test",
        2,
        4,
        rows,
        AShift::Range(0.01, 0.04),
        2.0,
        CommModel::Stochastic,
        seed,
    )
}

#[test]
fn coordinator_over_pjrt_recovers_products() {
    let Some(svc) = service() else { return };
    let cfg = CoordinatorConfig {
        scenario: scenario(1, 192.0),
        spec: PlanSpec {
            policy: Policy::DediIter,
            values: ValueModel::Markov,
            loads: LoadMethod::Markov,
        },
        cols: 96,
        time_scale: 2e-5,
        backend: Backend::Pjrt(svc.handle()),
        seed: 1,
        verify: true,
    };
    let report = coordinator::run(&cfg).unwrap();
    assert!(report.all_verified(1e-2), "{report:?}");
    // The runtime actually ran: at least encode + several matvecs.
    let (compiles, executions) = svc.handle().stats().unwrap();
    assert!(compiles >= 2, "encode + matvec buckets");
    assert!(executions >= 4, "got {executions}");
}

#[test]
fn pjrt_and_native_backends_agree_on_decode() {
    // Same seed ⇒ same plan, data, code and sampled delays ⇒ both
    // backends must recover the identical truth.
    let Some(svc) = service() else { return };
    for (backend, name) in [
        (Backend::Pjrt(svc.handle()), "pjrt"),
        (Backend::Native, "native"),
    ] {
        let cfg = CoordinatorConfig {
            scenario: scenario(2, 128.0),
            spec: PlanSpec {
                policy: Policy::Frac,
                values: ValueModel::Markov,
                loads: LoadMethod::Sca,
            },
            cols: 64,
            time_scale: 2e-5,
            backend,
            seed: 2,
            verify: true,
        };
        let report = coordinator::run(&cfg).unwrap();
        assert!(report.all_verified(1e-2), "{name}: {report:?}");
    }
}

#[test]
fn batched_matvec_bucket_serves_iterated_workload() {
    // Remark 2 (iterated mat-vec): the batch-8 artifact computes 8 model
    // vectors in one execution.
    let Some(svc) = service() else { return };
    let h = svc.handle();
    let (rows, cols, batch) = (200usize, 500usize, 8usize);
    let a: Vec<f32> = (0..rows * cols).map(|i| ((i % 13) as f32) * 0.1).collect();
    let x: Vec<f32> = (0..cols * batch).map(|i| ((i % 7) as f32) * 0.2).collect();
    let y = h.matvec(a.clone(), rows, cols, x.clone(), batch).unwrap();
    assert_eq!(y.len(), rows * batch);
    // Spot-check one entry against a direct computation.
    let (i, j) = (3usize, 5usize);
    let want: f32 = (0..cols).map(|k| a[i * cols + k] * x[k * batch + j]).sum();
    assert!((y[i * batch + j] - want).abs() < 1e-2 * (1.0 + want.abs()));
}
