//! Health-layer integration: fault injection, detection and recovery
//! over the TCP transport against in-process loopback workers, plus the
//! no-op parity contract (no fault plan → the PR-6 dispatch path, no
//! health bookkeeping at all).
//!
//! Timing in these tests is real wall clock, so assertions target
//! *outcomes* (the run decodes, the right event kinds were logged),
//! never exact event counts or orderings — a loaded CI box may trip a
//! false-positive detection, which by design only re-queues rows that
//! redundancy would have covered and cannot break the decode.

use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::{run_plan, Backend, RunOptions, Transport};
use coded_coop::health::{FaultPlan, HealthConfig, HealthEventKind};
use coded_coop::net::{WorkerConfig, WorkerServer};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};

/// Launch `n` loopback worker servers, each serving connections forever
/// from a detached thread, all carrying the same fault plan (faults
/// resolve per logical wid at handshake, so a plan targeting `w3` is
/// harmless on every other connection).
fn loopback_workers(n: usize, fault: Option<FaultPlan>) -> Vec<String> {
    (0..n)
        .map(|_| {
            let server = WorkerServer::bind("127.0.0.1:0").expect("bind loopback");
            let addr = server.local_addr().expect("local addr").to_string();
            let cfg = WorkerConfig {
                backend: Backend::Native,
                once: false,
                fault: fault.clone(),
                auth: None,
            };
            std::thread::spawn(move || {
                let _ = server.run(&cfg);
            });
            addr
        })
        .collect()
}

fn scenario(name: &str, masters: usize, workers: usize, l: f64, seed: u64) -> Scenario {
    Scenario::random(
        name,
        masters,
        workers,
        l,
        AShift::Range(0.01, 0.05),
        2.0,
        CommModel::Stochastic,
        seed,
    )
}

fn spec() -> PlanSpec {
    PlanSpec {
        policy: Policy::DediIter,
        values: coded_coop::assign::ValueModel::Markov,
        loads: LoadMethod::Markov,
    }
}

fn opts(seed: u64, transport: Transport, fault: Option<FaultPlan>) -> RunOptions {
    RunOptions {
        cols: 16,
        time_scale: 2e-5,
        backend: Backend::Native,
        seed,
        verify: true,
        transport,
        fault,
        health: HealthConfig::fast(),
    }
}

fn kinds(report: &coded_coop::coordinator::Report) -> Vec<&'static str> {
    report.health.iter().map(|h| h.kind_label()).collect()
}

#[test]
fn tcp_crash_is_requeued_and_decodes() {
    // w3 (wid 2) severs its connection before computing anything: the
    // reader sees the EOF, the breaker opens, and every one of its
    // sub-tasks must be re-queued onto surviving workers over fresh
    // connections — the decode then completes and verifies.
    let fault = FaultPlan::parse("crash:w3@0%").unwrap();
    let s = scenario("health-crash", 2, 4, 64.0, 13);
    let p = plan::build(&s, &spec());
    let addrs = loopback_workers(3, Some(fault.clone()));
    let mut o = opts(13, Transport::tcp(addrs), Some(fault));
    // Slow the virtual clock down so deadlines sit well past the crash:
    // the fleet cannot finish before the disconnect drain lands.
    o.time_scale = 2e-3;
    let report = run_plan(&s, &p, &o).unwrap();

    assert!(report.all_verified(1e-3), "{report:?}");
    let k = kinds(&report);
    assert!(k.contains(&"disconnect"), "no disconnect logged: {k:?}");
    assert!(k.contains(&"open"), "breaker never opened: {k:?}");
    assert!(k.contains(&"requeue"), "nothing re-queued: {k:?}");
    for h in &report.health {
        if let HealthEventKind::Requeue { rows, to } = &h.kind {
            assert!(*rows > 0, "empty re-queue event: {h:?}");
            assert_ne!(*to, 2, "re-queued onto the crashed worker: {h:?}");
        }
    }
    // The crashed queue contributed nothing; its share moved elsewhere.
    assert_eq!(report.worker_computed[2], 0, "{report:?}");
}

#[test]
fn tcp_gray_failure_is_detected_and_released() {
    // Both remote workers go gray from sub-task 0: heartbeats keep
    // flowing but no result ever publishes, so only the deadline-stall
    // verdict can catch them. The master's local queue alone holds
    // fewer than L coded rows — without detection + re-queue this run
    // cannot decode, so `all_verified` here proves the whole loop:
    // stall verdict → breaker open → mid-run release → re-queue.
    let fault = FaultPlan::parse("gray:w1@0%,gray:w2@0%").unwrap();
    let s = scenario("health-gray", 1, 2, 64.0, 7);
    let p = plan::build(&s, &spec());
    let addrs = loopback_workers(2, Some(fault.clone()));
    let report = run_plan(&s, &p, &opts(7, Transport::tcp(addrs), Some(fault))).unwrap();

    assert!(report.all_verified(1e-3), "{report:?}");
    let k = kinds(&report);
    assert!(k.contains(&"suspect"), "no stall verdict logged: {k:?}");
    assert!(k.contains(&"open"), "breaker never opened: {k:?}");
    assert!(k.contains(&"requeue"), "nothing re-queued: {k:?}");
    // The gray workers were suspected by the tracker, not the reader.
    assert!(
        report
            .health
            .iter()
            .any(|h| matches!(&h.kind, HealthEventKind::Suspect { why } if why.contains("Stalled"))),
        "expected a Stalled verdict: {:?}",
        report.health
    );
}

#[test]
fn no_fault_is_disarmed_and_matches_thread_transport() {
    // The no-op parity criterion: with no fault plan and `armed` off,
    // the health layer must not exist — no events, no beats, and the
    // exact same sub-task assignment as the thread transport.
    let s = scenario("health-parity", 2, 4, 64.0, 11);
    let p = plan::build(&s, &spec());
    let mut thread_opts = opts(11, Transport::Thread, None);
    thread_opts.health = HealthConfig::default();
    let thread_report = run_plan(&s, &p, &thread_opts).unwrap();
    let mut tcp_opts = opts(11, Transport::tcp(loopback_workers(3, None)), None);
    tcp_opts.health = HealthConfig::default();
    let tcp_report = run_plan(&s, &p, &tcp_opts).unwrap();

    assert!(thread_report.all_verified(1e-3), "{thread_report:?}");
    assert!(tcp_report.all_verified(1e-3), "{tcp_report:?}");
    assert!(thread_report.health.is_empty(), "{:?}", thread_report.health);
    assert!(tcp_report.health.is_empty(), "{:?}", tcp_report.health);

    let key = |events: &[coded_coop::coordinator::worker::TaskEvent]| {
        let mut v: Vec<_> = events
            .iter()
            .map(|e| (e.worker, e.master, e.rows, e.deadline_ms.to_bits()))
            .collect();
        v.sort();
        v
    };
    assert_eq!(
        key(&thread_report.events),
        key(&tcp_report.events),
        "disarmed TCP executed a different assignment than the thread path"
    );
}

#[test]
fn requeued_run_decodes_like_a_healthy_one() {
    // Deterministic re-queue parity: same scenario, same seed, one
    // fleet healthy and one with a crashed worker. Both must decode
    // against the same ground truth with exactly L rows per master —
    // re-queued duplicates would make the LU system singular, dropped
    // rows would leave it underdetermined.
    let s = scenario("health-requeue-parity", 2, 4, 64.0, 5);
    let p = plan::build(&s, &spec());

    let healthy_addrs = loopback_workers(3, None);
    let healthy = run_plan(&s, &p, &{
        let mut o = opts(5, Transport::tcp(healthy_addrs), None);
        o.time_scale = 2e-3;
        o
    })
    .unwrap();

    let fault = FaultPlan::parse("crash:w3@0%").unwrap();
    let crashed_addrs = loopback_workers(3, Some(fault.clone()));
    let crashed = run_plan(&s, &p, &{
        let mut o = opts(5, Transport::tcp(crashed_addrs), Some(fault));
        o.time_scale = 2e-3;
        o
    })
    .unwrap();

    assert!(healthy.all_verified(1e-3), "{healthy:?}");
    assert!(crashed.all_verified(1e-3), "{crashed:?}");
    assert_eq!(healthy.masters.len(), crashed.masters.len());
    for (h, c) in healthy.masters.iter().zip(&crashed.masters) {
        assert_eq!(h.rows_used, c.rows_used, "decode consumed different row counts");
        assert!(c.completion_ms.is_finite());
    }
    assert!(healthy.health.is_empty());
    assert!(!crashed.health.is_empty());
}

#[test]
fn thread_mode_crash_is_logged_and_absorbed_by_redundancy() {
    // The thread transport has no re-queue (an in-process "crash" is
    // just an early return): the fault surfaces as a Disconnect health
    // event and the lost rows behave like stragglers. The report must
    // stay coherent either way — redundancy may or may not cover the
    // hole, so completion is not asserted.
    let fault = FaultPlan::parse("crash:w2@0%").unwrap();
    let s = scenario("health-thread-crash", 2, 4, 64.0, 3);
    let p = plan::build(&s, &spec());
    let report = run_plan(&s, &p, &opts(3, Transport::Thread, Some(fault))).unwrap();

    assert_eq!(report.masters.len(), 2);
    assert!(
        report
            .health
            .iter()
            .any(|h| h.worker == 1 && matches!(h.kind, HealthEventKind::Disconnect)),
        "thread-mode crash must log a Disconnect: {:?}",
        report.health
    );
    assert_eq!(report.worker_computed[1], 0, "{report:?}");
    for m in &report.masters {
        // Coherence even if a master never decoded (completion = ∞).
        assert!(m.rows_used <= 64);
    }
}
