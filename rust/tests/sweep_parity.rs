//! Golden-parity tests for the experiment layer.
//!
//! The sweep rewrites of fig2/fig4/fig6 must reproduce the legacy
//! per-figure loops cell-for-cell. The fixture here IS the legacy path,
//! preserved verbatim as `figures::common::evaluate` (build the policy
//! spec, run it through a serial `sim::run` with the figure-harness seed
//! derivation). Same scenarios, same plans, same RNG streams ⇒ the
//! batched engine must match it to the last bit — these are exact
//! equalities, not tolerances.

use coded_coop::assign::ValueModel;
use coded_coop::config::{CommModel, Scenario};
use coded_coop::experiment::{self, catalog, SweepOptions, SweepResult};
use coded_coop::figures::common::{self, FigureOptions};

const TRIALS: usize = 2_000;
const SEED: u64 = 9;
/// Parity holds for ANY stream count as long as both sides pin the same
/// one; 2 exercises the multi-shard split + merge paths.
const THREADS: usize = 2;

fn opts() -> FigureOptions {
    FigureOptions {
        trials: TRIALS,
        seed: SEED,
        fit_samples: 100,
        threads: THREADS,
    }
}

fn run_id(id: &str) -> SweepResult {
    let spec = catalog::spec(id, TRIALS, SEED).unwrap();
    experiment::run_sweep(
        &spec,
        &SweepOptions {
            threads: THREADS,
            cell_streams: THREADS,
            fused: false,
        },
    )
    .unwrap()
}

fn assert_cell_matches(
    cell: &experiment::CellResult,
    fixture: &common::Evaluated,
    ctx: &str,
) {
    assert_eq!(
        cell.outcome.system.mean(),
        fixture.results.system.mean(),
        "{ctx}: system mean"
    );
    assert_eq!(
        cell.outcome.system.sem(),
        fixture.results.system.sem(),
        "{ctx}: system sem"
    );
    assert_eq!(
        cell.outcome.system.count(),
        fixture.results.system.count(),
        "{ctx}: realizations"
    );
    assert_eq!(
        cell.outcome.per_master.len(),
        fixture.results.per_master.len(),
        "{ctx}: master count"
    );
    for (m, (a, b)) in cell
        .outcome
        .per_master
        .iter()
        .zip(&fixture.results.per_master)
        .enumerate()
    {
        assert_eq!(a.mean(), b.mean(), "{ctx}: master {m} mean");
    }
    assert_eq!(cell.outcome.label, fixture.label, "{ctx}: label");
    assert_eq!(cell.plan, fixture.plan, "{ctx}: plan");
    assert_eq!(cell.outcome.t_est_ms, fixture.plan.t_est(), "{ctx}: t_est");
}

#[test]
fn fig2_sweep_matches_legacy_loop_bit_for_bit() {
    // Legacy fixture: the exact loop fig2 ran before the sweep rewrite —
    // one scenario, three variants, samples kept.
    let s = Scenario::small_scale(SEED, 2.0, CommModel::CompDominant);
    let result = run_id("fig2");
    let variants = catalog::validation_variants();
    assert_eq!(result.cells.len(), variants.len());
    for ((name, spec), cell) in variants.into_iter().zip(&result.cells) {
        let fixture = common::evaluate(&s, &spec, &opts(), true);
        assert_cell_matches(cell, &fixture, name);
        // Samples too: the CDF panel must be identical.
        assert_eq!(
            cell.outcome.samples.as_ref().unwrap(),
            fixture.results.samples.as_ref().unwrap(),
            "{name}: samples"
        );
    }
}

#[test]
fn fig4_sweeps_match_legacy_loops_bit_for_bit() {
    for (id, small) in [("fig4a", true), ("fig4b", false)] {
        let s = if small {
            Scenario::small_scale(SEED, 2.0, CommModel::Stochastic)
        } else {
            Scenario::large_scale(SEED, 2.0, CommModel::Stochastic)
        };
        let result = run_id(id);
        let roster = catalog::roster(small, ValueModel::Markov, "markov");
        assert_eq!(result.cells.len(), roster.len(), "{id}");
        for (spec, cell) in roster.iter().zip(&result.cells) {
            let fixture = common::evaluate(&s, spec, &opts(), false);
            assert_cell_matches(cell, &fixture, &format!("{id}/{}", fixture.label));
        }
    }
}

#[test]
fn fig6_sweep_matches_legacy_loop_bit_for_bit() {
    // Legacy loop: per ratio, rebuild the scenario at the same seed (so
    // only γ changes), evaluate the 4-policy roster.
    let result = run_id("fig6");
    let roster = catalog::fig6_roster();
    assert_eq!(result.cells.len(), catalog::FIG6_RATIOS.len() * roster.len());
    let mut ci = 0;
    for &ratio in catalog::FIG6_RATIOS {
        let s = Scenario::large_scale(SEED, ratio, CommModel::Stochastic);
        for spec in &roster {
            let cell = &result.cells[ci];
            ci += 1;
            assert_eq!(cell.axis("gamma_ratio"), Some(ratio));
            let fixture = common::evaluate(&s, spec, &opts(), false);
            assert_cell_matches(
                cell,
                &fixture,
                &format!("fig6 γ/u={ratio} {}", fixture.label),
            );
        }
    }
    assert_eq!(ci, result.cells.len());
}

#[test]
fn redundancy_sweep_matches_legacy_loop_bit_for_bit() {
    // The legacy ablation built one Theorem-1 plan and rescaled its
    // loads per β; MC seed was the raw harness seed (no figure xor).
    let s = Scenario::large_scale(SEED, 2.0, CommModel::Stochastic);
    let base = coded_coop::policy::PolicySpec::new("dedi-iter", ValueModel::Markov, "markov")
        .build(&s)
        .unwrap();
    let result = run_id("ablation_redundancy");
    assert_eq!(result.cells.len(), catalog::REDUNDANCY_BETAS.len());
    for (&beta, cell) in catalog::REDUNDANCY_BETAS.iter().zip(&result.cells) {
        let fixture_plan = base.with_overhead(beta);
        let direct = coded_coop::sim::run(
            &s,
            &fixture_plan,
            &coded_coop::sim::McOptions {
                trials: TRIALS,
                seed: SEED,
                keep_samples: true,
                threads: THREADS,
                ziggurat: false,
            },
        );
        assert_eq!(cell.plan, fixture_plan, "β={beta}: plan");
        assert_eq!(
            cell.outcome.system.mean(),
            direct.system.mean(),
            "β={beta}: mean"
        );
        assert_eq!(
            cell.outcome.samples.as_ref().unwrap(),
            direct.samples.as_ref().unwrap(),
            "β={beta}: samples"
        );
    }
}

#[test]
fn sweep_is_deterministic_across_runs_and_pool_sizes() {
    let a = run_id("fig4a");
    let b = run_id("fig4a");
    let wide = experiment::run_sweep(
        &catalog::spec("fig4a", TRIALS, SEED).unwrap(),
        &SweepOptions {
            threads: 8, // different pool, same cell_streams
            cell_streams: THREADS,
            fused: false,
        },
    )
    .unwrap();
    let fused = experiment::run_sweep(
        &catalog::spec("fig4a", TRIALS, SEED).unwrap(),
        &SweepOptions {
            threads: THREADS,
            cell_streams: THREADS,
            fused: true, // kernel v3 fused arena: still bit-identical
        },
    )
    .unwrap();
    for (((x, y), z), w) in a
        .cells
        .iter()
        .zip(&b.cells)
        .zip(&wide.cells)
        .zip(&fused.cells)
    {
        assert_eq!(x.outcome.system.mean(), y.outcome.system.mean());
        assert_eq!(x.outcome.system.mean(), z.outcome.system.mean());
        assert_eq!(x.outcome.system.mean(), w.outcome.system.mean());
        assert_eq!(x.outcome.system.sem(), w.outcome.system.sem());
    }
}
