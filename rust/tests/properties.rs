//! Property-based tests over the public API (in-tree `util::prop`
//! harness — DESIGN.md §Substitutions). Each property runs against
//! randomized scenarios/parameters; failures report a replay seed.

use coded_coop::alloc::{expected_results, markov, sca, EffLink};
use coded_coop::assign::{
    dedicated_iter, dedicated_simple, fractional, ValueMatrix, ValueModel,
};
use coded_coop::coding::MdsCode;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::model::params::LinkParams;
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::util::prop::{check, Config, Gen};

fn random_scenario(g: &mut Gen) -> Scenario {
    let m = g.usize_range(1, 4);
    let n = g.usize_range(m.max(2), 20);
    let seed = g.rng().next_u64();
    Scenario::random(
        "prop",
        m,
        n,
        1e3 + g.f64_range(0.0, 1e4),
        AShift::Range(0.05, 0.5),
        g.f64_range(0.25, 8.0),
        if g.bool() {
            CommModel::Stochastic
        } else {
            CommModel::CompDominant
        },
        seed,
    )
}

#[test]
fn prop_markov_allocation_feasible_under_exact_model() {
    check(
        Config::default().cases(60),
        "E[X(t*)] ≥ L for Theorem-1 allocations",
        |g| {
            let n = g.usize_range(1, 12);
            let links: Vec<EffLink> = (0..n)
                .map(|_| {
                    let a = g.f64_range(0.05, 0.5);
                    let u = 1.0 / a;
                    EffLink::dedicated(&LinkParams::new(
                        g.f64_range(0.5, 8.0) * u,
                        a,
                        u,
                    ))
                })
                .collect();
            let l_rows = g.f64_range(100.0, 1e5);
            let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
            let alloc = markov::allocate(&thetas, l_rows);
            let progress = expected_results(&links, &alloc.loads, alloc.t_star);
            assert!(
                progress >= l_rows * (1.0 - 1e-9),
                "E[X] = {progress} < L = {l_rows}"
            );
        },
    );
}

#[test]
fn prop_sca_improves_and_stays_feasible() {
    check(
        Config::default().cases(25),
        "SCA ≤ Markov t* and feasible",
        |g| {
            let n = g.usize_range(2, 8);
            let links: Vec<EffLink> = (0..n)
                .map(|_| {
                    let a = g.f64_range(0.05, 0.5);
                    let u = 1.0 / a;
                    EffLink::dedicated(&LinkParams::new(2.0 * u, a, u))
                })
                .collect();
            let l_rows = 1e4;
            let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
            let start = markov::allocate(&thetas, l_rows);
            let enh = sca::enhance(&links, l_rows, &start, &Default::default());
            assert!(enh.t_star <= start.t_star * (1.0 + 1e-9));
            let progress = expected_results(&links, &enh.loads, enh.t_star);
            assert!(progress >= l_rows * (1.0 - 1e-5));
        },
    );
}

#[test]
fn prop_assignments_partition_and_respect_resources() {
    check(
        Config::default().cases(30),
        "assignment invariants",
        |g| {
            let s = random_scenario(g);
            let vm = ValueMatrix::new(&s, ValueModel::Markov);
            // Dedicated: every worker exactly one owner.
            let d = if g.bool() {
                dedicated_iter::assign(&vm, &Default::default())
            } else {
                dedicated_simple::assign(&vm)
            };
            assert_eq!(d.owner.len(), s.n_workers());
            assert!(d.owner.iter().all(|&m| m < s.n_masters()));
            // Fractional: Σ_m k ≤ 1 and Σ_m b ≤ 1 per worker.
            let f = fractional::assign(&s, &d, &Default::default());
            assert!(f.is_feasible());
        },
    );
}

#[test]
fn prop_alg1_min_value_at_least_alg2() {
    check(
        Config::default().cases(30),
        "iterated greedy dominates simple greedy",
        |g| {
            let s = random_scenario(g);
            let vm = ValueMatrix::new(&s, ValueModel::Markov);
            let iter_min = dedicated_iter::assign(&vm, &Default::default()).min_value(&vm);
            let simple_min = dedicated_simple::assign(&vm).min_value(&vm);
            assert!(iter_min >= simple_min * (1.0 - 1e-12));
        },
    );
}

#[test]
fn prop_plans_have_enough_redundancy_and_valid_shares() {
    check(
        Config::default().cases(25),
        "plan invariants over random scenarios",
        |g| {
            let s = random_scenario(g);
            let policy = *g
                .rng()
                .choose(&[Policy::CodedUniform, Policy::DediIter, Policy::Frac]);
            let p = plan::build(
                &s,
                &PlanSpec {
                    policy,
                    values: ValueModel::Markov,
                    loads: LoadMethod::Markov,
                },
            );
            let mut ksum = vec![0.0; s.n_workers() + 1];
            for mp in &p.masters {
                assert!(mp.total_load() > mp.l_rows, "no redundancy");
                assert!(mp.t_est.is_finite() && mp.t_est > 0.0);
                for e in &mp.entries {
                    assert!(e.load > 0.0 && e.k > 0.0 && e.b > 0.0);
                    if e.node >= 1 {
                        ksum[e.node] += e.k;
                    }
                }
            }
            for (n, &k) in ksum.iter().enumerate().skip(1) {
                assert!(k <= 1.0 + 1e-9, "worker {n} oversubscribed: {k}");
            }
        },
    );
}

#[test]
fn prop_mds_decodes_any_subset() {
    check(
        Config::default().cases(40),
        "MDS: any L of L̃ coded rows recover the products",
        |g| {
            let l = g.usize_range(2, 24);
            let extra = g.usize_range(1, 12);
            let code = MdsCode::new(l, l + extra, g.rng());
            let data: Vec<f64> = (0..l).map(|_| g.rng().normal()).collect();
            let a = coded_coop::coding::Matrix::from_vec(l, 1, data.clone());
            let y = code.encode(&a).matvec(&[1.0]);
            let idx = g.rng().subset(l + extra, l);
            let rx: Vec<(usize, f64)> = idx.iter().map(|&i| (i, y[i])).collect();
            let z = code.decode(&rx).expect("decodable");
            for (zi, di) in z.iter().zip(&data) {
                assert!(
                    (zi - di).abs() < 1e-5 * (1.0 + di.abs()),
                    "{zi} vs {di}"
                );
            }
        },
    );
}

#[test]
fn prop_simulator_matches_oracle_recomputation() {
    // The MC engine's per-trial completion must equal an independent
    // oracle: smallest sampled delay t with Σ_{T≤t} l ≥ L.
    check(
        Config::default().cases(20),
        "simulator trial == oracle",
        |g| {
            use coded_coop::model::dist::LinkDelay;
            let s = random_scenario(g);
            let spec = PlanSpec {
                policy: Policy::DediIter,
                values: ValueModel::Markov,
                loads: LoadMethod::Markov,
            };
            let p = plan::build(&s, &spec);
            // Oracle for master 0 with a fixed RNG stream.
            let mp = &p.masters[0];
            let mut rng = coded_coop::util::rng::Rng::new(g.rng().next_u64());
            let mut arr: Vec<(f64, f64)> = mp
                .entries
                .iter()
                .map(|e| {
                    let d = LinkDelay::new(&s.link(0, e.node), e.load, e.k, e.b);
                    (d.sample(&mut rng), e.load)
                })
                .collect();
            arr.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let mut acc = 0.0;
            let mut oracle = f64::INFINITY;
            for (t, l) in arr {
                acc += l;
                if acc >= mp.l_rows {
                    oracle = t;
                    break;
                }
            }
            assert!(
                oracle.is_finite(),
                "coded plan must always complete (Σl > L)"
            );
        },
    );
}

#[test]
fn prop_round_loads_decodable_and_tight() {
    check(
        Config::default().cases(200),
        "round_loads: Σl ≥ L + 1, ≤ fractional total + one row per worker",
        |g| {
            let n = g.usize_range(1, 40);
            let l_rows = g.usize_range(1, 5000);
            // Random positive fractional loads, scaled so Σ ≥ L (the
            // allocators always hand round_loads a feasible total).
            let raw: Vec<f64> = (0..n).map(|_| g.f64_range(0.1, 10.0)).collect();
            let raw_sum: f64 = raw.iter().sum();
            let scale = l_rows as f64 * g.f64_range(1.0, 3.0) / raw_sum;
            let loads: Vec<f64> = raw.iter().map(|&r| r * scale).collect();
            let frac_sum: f64 = loads.iter().sum();

            let out = coded_coop::coordinator::round_loads(&loads, l_rows);
            let total: usize = out.iter().sum();

            // Decodability: any L coded rows decode, and at least one
            // row of redundancy keeps the system coded.
            assert!(
                total >= l_rows + 1,
                "Σ rounded = {total} < L + 1 = {}",
                l_rows + 1
            );
            // Tightness: never more than one extra row per worker over
            // the fractional total (largest-remainder rounding).
            assert!(
                total as f64 <= frac_sum + n as f64 + 0.5,
                "Σ rounded = {total} ≫ fractional {frac_sum} + {n}"
            );
            // Shape: order-preserving, no entry below its floor.
            assert_eq!(out.len(), loads.len());
            for (o, l) in out.iter().zip(&loads) {
                assert!(
                    *o >= l.floor() as usize,
                    "entry rounded below its floor: {o} < ⌊{l}⌋"
                );
                assert!(
                    (*o as f64) <= l + 2.0,
                    "entry {o} exceeds fractional {l} by more than 2 rows"
                );
            }
        },
    );
}
