//! Cross-module integration tests: planner → simulator → figures → config
//! files, exercising the public API the way the examples do.

use coded_coop::assign::ValueModel;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::figures::{self, FigureOptions};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::{self, McOptions};

fn mc(trials: usize) -> McOptions {
    McOptions {
        trials,
        seed: 99,
        keep_samples: false,
        threads: 0,
        ziggurat: false,
    }
}

fn spec(policy: Policy, loads: LoadMethod) -> PlanSpec {
    PlanSpec {
        policy,
        values: ValueModel::Markov,
        loads,
    }
}

#[test]
fn every_policy_plans_and_simulates_on_every_scenario() {
    let scenarios = [
        Scenario::small_scale(1, 2.0, CommModel::Stochastic),
        Scenario::small_scale(1, 2.0, CommModel::CompDominant),
        Scenario::large_scale(1, 2.0, CommModel::Stochastic),
        Scenario::ec2(10, 4, false),
        Scenario::ec2(10, 4, true),
    ];
    for s in &scenarios {
        for policy in [
            Policy::UncodedUniform,
            Policy::CodedUniform,
            Policy::DediSimple,
            Policy::DediIter,
            Policy::Frac,
        ] {
            let p = plan::build(s, &spec(policy, LoadMethod::Markov));
            let r = sim::run(s, &p, &mc(500));
            assert!(
                r.system.mean().is_finite() && r.system.mean() > 0.0,
                "{} / {policy:?}",
                s.name
            );
        }
    }
}

#[test]
fn sca_never_worse_than_markov_planner_estimate() {
    for seed in 0..5 {
        let s = Scenario::small_scale(seed, 2.0, CommModel::Stochastic);
        let base = plan::build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let enh = plan::build(&s, &spec(Policy::DediIter, LoadMethod::Sca));
        assert!(enh.t_est() <= base.t_est() * (1.0 + 1e-9), "seed {seed}");
    }
}

#[test]
fn empirical_completion_consistent_with_estimates_across_policies() {
    // Monte-Carlo means must track the planner's t* within a factor of 2
    // in both directions for the coded policies (the Markov t* is
    // conservative; the SCA t* is tight).
    let s = Scenario::large_scale(7, 2.0, CommModel::Stochastic);
    for loads in [LoadMethod::Markov, LoadMethod::Sca] {
        let p = plan::build(&s, &spec(Policy::DediIter, loads));
        let r = sim::run(&s, &p, &mc(5_000));
        let (est, got) = (p.t_est(), r.system.mean());
        assert!(
            got < 2.0 * est && got > 0.3 * est,
            "{loads:?}: est {est} vs emp {got}"
        );
    }
}

#[test]
fn scenario_json_file_roundtrip() {
    let s = Scenario::large_scale(3, 4.0, CommModel::Stochastic);
    let dir = std::env::temp_dir().join("coded_coop_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("scenario.json");
    std::fs::write(&path, s.to_json().to_string_pretty()).unwrap();
    let back = Scenario::from_file(path.to_str().unwrap()).unwrap();
    assert_eq!(back.n_masters(), 4);
    assert_eq!(back.n_workers(), 50);
    // Same plan comes out of the round-tripped config.
    let p1 = plan::build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
    let p2 = plan::build(&back, &spec(Policy::DediIter, LoadMethod::Markov));
    assert!((p1.t_est() - p2.t_est()).abs() < 1e-9);
}

#[test]
fn figure_harness_saves_artifacts() {
    let dir = std::env::temp_dir().join("coded_coop_figs");
    let opts = FigureOptions {
        trials: 300,
        seed: 2,
        fit_samples: 2_000,
        threads: 0,
    };
    let fig = figures::run("fig7", &opts).unwrap();
    fig.save(dir.to_str().unwrap()).unwrap();
    let json = std::fs::read_to_string(dir.join("fig7.json")).unwrap();
    let parsed = coded_coop::util::json::parse(&json).unwrap();
    assert_eq!(
        parsed.get("id").and_then(|j| j.as_str()),
        Some("fig7")
    );
    assert!(std::fs::metadata(dir.join("fig7.txt")).unwrap().len() > 0);
}

#[test]
fn uncoded_needs_every_worker_coded_does_not() {
    // Make one worker catastrophically slow: the uncoded scheme's delay
    // explodes, the coded schemes route around it.
    let mut s = Scenario::random(
        "one-bad-worker",
        1,
        6,
        1e3,
        AShift::Range(0.1, 0.2),
        2.0,
        CommModel::Stochastic,
        5,
    );
    // Worker 6 is 100× slower.
    let bad = s.links[0][5];
    s.links[0][5] = coded_coop::model::params::LinkParams::new(
        bad.gamma,
        bad.a * 100.0,
        bad.u / 100.0,
    );
    let unc = plan::build(&s, &spec(Policy::UncodedUniform, LoadMethod::Markov));
    let ded = plan::build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
    let r_unc = sim::run(&s, &unc, &mc(2_000));
    let r_ded = sim::run(&s, &ded, &mc(2_000));
    assert!(
        r_ded.system.mean() < 0.3 * r_unc.system.mean(),
        "coded {} vs uncoded {}",
        r_ded.system.mean(),
        r_unc.system.mean()
    );
}

#[test]
fn fractional_plan_outperforms_or_matches_dedicated_small_scale() {
    // §IV motivation: with few workers the fractional policy balances
    // masters better. Compare empirical means over seeds (allow ties).
    let mut frac_wins = 0;
    for seed in 0..6 {
        let s = Scenario::small_scale(seed, 2.0, CommModel::Stochastic);
        let d = plan::build(&s, &spec(Policy::DediIter, LoadMethod::Markov));
        let f = plan::build(&s, &spec(Policy::Frac, LoadMethod::Markov));
        let rd = sim::run(&s, &d, &mc(4_000)).system.mean();
        let rf = sim::run(&s, &f, &mc(4_000)).system.mean();
        if rf <= rd * 1.01 {
            frac_wins += 1;
        }
    }
    assert!(frac_wins >= 4, "fractional lost too often: {frac_wins}/6");
}
