//! `cargo bench --bench hotpaths` — microbenchmarks of every hot path
//! identified in DESIGN.md §9, used to drive the §Perf pass in
//! EXPERIMENTS.md.
//!
//! Groups:
//! * PRNG + delay sampling (the MC engine's inner loop)
//! * Monte-Carlo engine end-to-end (trials/s)
//! * assignment algorithms at N = 50 / 200 / 1000
//! * SCA-enhanced allocation
//! * MDS decode (LU solve) at L = 128 / 512
//! * PJRT artifact execution (matvec bucket) vs native loop

use std::time::Duration;

use coded_coop::alloc::{markov, sca, EffLink};
use coded_coop::assign::{
    dedicated_iter, dedicated_simple, fractional, ValueMatrix, ValueModel,
};
use coded_coop::coding::MdsCode;
use coded_coop::config::{AShift, CommModel, Scenario};
use coded_coop::coordinator::native_matmul;
use coded_coop::model::dist::LinkDelay;
use coded_coop::model::params::LinkParams;
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::runtime::{default_artifact_dir, Runtime};
use coded_coop::sim::{self, McOptions};
use coded_coop::util::benchkit::{black_box, group, Bench};
use coded_coop::util::rng::Rng;

fn quick() -> Bench {
    Bench::new()
        .warmup(Duration::from_millis(100))
        .measure_time(Duration::from_millis(800))
}

fn main() {
    bench_sampling();
    bench_completion_scan();
    bench_mc_engine();
    bench_assignment();
    bench_sca();
    bench_decode();
    bench_runtime();
}

fn bench_sampling() {
    group("PRNG + delay sampling");
    let mut rng = Rng::new(1);
    let r = quick()
        .items(1024.0)
        .run("rng::f64 x1024", || {
            let mut acc = 0.0;
            for _ in 0..1024 {
                acc += rng.f64();
            }
            acc
        });
    println!("{}", r.report());

    let p = LinkParams::new(2.0, 0.25, 4.0);
    let d = LinkDelay::new(&p, 100.0, 1.0, 1.0);
    let r = quick().items(1024.0).run("LinkDelay::sample x1024", || {
        let mut acc = 0.0;
        for _ in 0..1024 {
            acc += d.sample(&mut rng);
        }
        acc
    });
    println!("{}", r.report());
}

fn bench_completion_scan() {
    group("completion resolution: selection scan vs full sort (N=50, 2× redundancy)");
    let mut rng = Rng::new(3);
    let n = 50usize;
    let times: Vec<f64> = (0..n).map(|_| rng.exp(0.5)).collect();
    let loads: Vec<f64> = (0..n).map(|_| rng.range(50.0, 150.0)).collect();
    let target = loads.iter().sum::<f64>() / 2.0;
    let mut ts = vec![0.0; n];
    let mut ls = vec![0.0; n];
    let r = quick().items(1.0).run("selection scan", || {
        ts.copy_from_slice(&times);
        ls.copy_from_slice(&loads);
        coded_coop::sim::engine::completion_scan(black_box(&mut ts), &mut ls, target)
    });
    println!("{}", r.report());
    let mut pairs: Vec<(f64, f64)> = Vec::with_capacity(n);
    let r = quick().items(1.0).run("sort + prefix scan (legacy)", || {
        pairs.clear();
        pairs.extend(times.iter().copied().zip(loads.iter().copied()));
        pairs.sort_unstable_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut acc = 0.0;
        for &(t, l) in black_box(&pairs) {
            acc += l;
            if acc >= target {
                return t;
            }
        }
        f64::INFINITY
    });
    println!("{}", r.report());
}

fn bench_mc_engine() {
    group("Monte-Carlo engine (large scale, Dedi-iter plan)");
    let s = Scenario::large_scale(2022, 2.0, CommModel::Stochastic);
    let spec = PlanSpec {
        policy: Policy::DediIter,
        values: ValueModel::Markov,
        loads: LoadMethod::Markov,
    };
    let p = plan::build(&s, &spec);
    for threads in [1, 0] {
        let label = if threads == 1 {
            "sim::run 20k trials, 1 thread"
        } else {
            "sim::run 20k trials, all cores"
        };
        let opts = McOptions {
            trials: 20_000,
            seed: 5,
            keep_samples: false,
            threads,
            ziggurat: false,
        };
        let r = quick()
            .items(20_000.0)
            .run(label, || sim::run(&s, &p, &opts).system.mean());
        println!("{}", r.report());
    }
}

fn bench_assignment() {
    group("worker assignment");
    for n in [50usize, 200, 1000] {
        let s = Scenario::random(
            "bench",
            8,
            n,
            1e4,
            AShift::Range(0.05, 0.5),
            2.0,
            CommModel::Stochastic,
            7,
        );
        let vm = ValueMatrix::new(&s, ValueModel::Markov);
        let r = quick().run(&format!("Alg2 simple greedy N={n}"), || {
            dedicated_simple::assign(black_box(&vm))
        });
        println!("{}", r.report());
        let r = quick().run(&format!("Alg1 iterated greedy N={n}"), || {
            dedicated_iter::assign(black_box(&vm), &Default::default())
        });
        println!("{}", r.report());
    }
    let s = Scenario::large_scale(3, 2.0, CommModel::Stochastic);
    let vm = ValueMatrix::new(&s, ValueModel::Markov);
    let d = dedicated_iter::assign(&vm, &Default::default());
    let r = quick().run("Alg4 fractional N=50", || {
        fractional::assign(black_box(&s), black_box(&d), &Default::default())
    });
    println!("{}", r.report());
}

fn bench_sca() {
    group("load allocation");
    let mut rng = Rng::new(9);
    let links: Vec<EffLink> = (0..50)
        .map(|_| {
            let a = rng.range(0.05, 0.5);
            EffLink::dedicated(&LinkParams::new(2.0 / a, a, 1.0 / a))
        })
        .collect();
    let thetas: Vec<f64> = links.iter().map(EffLink::theta).collect();
    let r = quick().run("Thm1 closed form N=50", || {
        markov::allocate(black_box(&thetas), 1e4)
    });
    println!("{}", r.report());
    let r = quick().run("Alg3 SCA N=50", || {
        sca::allocate(black_box(&links), 1e4, &Default::default())
    });
    println!("{}", r.report());
}

fn bench_decode() {
    group("MDS decode (LU solve on received rows)");
    let mut rng = Rng::new(11);
    for l in [128usize, 512] {
        let code = MdsCode::new(l, l + l / 2, &mut rng);
        let y: Vec<f64> = (0..code.coded_len()).map(|_| rng.normal()).collect();
        // Worst case: all-parity decode (no systematic fast path).
        let rx: Vec<(usize, f64)> = (code.coded_len() - l..code.coded_len())
            .map(|i| (i, y[i]))
            .collect();
        let r = quick().items(l as f64).run(&format!("decode L={l} (parity rows)"), || {
            code.decode(black_box(&rx)).unwrap()
        });
        println!("{}", r.report());
        // Fast path: systematic rows arrive first.
        let rx: Vec<(usize, f64)> = (0..l).map(|i| (i, y[i])).collect();
        let r = quick()
            .items(l as f64)
            .run(&format!("decode L={l} (systematic fast path)"), || {
                code.decode(black_box(&rx)).unwrap()
            });
        println!("{}", r.report());
    }
}

fn bench_runtime() {
    group("PJRT artifact execution (512×512 mat-vec)");
    let mut rt = match Runtime::new(&default_artifact_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("skipped: {e}");
            return;
        }
    };
    let mut rng = Rng::new(13);
    let (rows, cols) = (512usize, 512usize);
    let a: Vec<f32> = (0..rows * cols).map(|_| rng.normal() as f32).collect();
    let x: Vec<f32> = (0..cols).map(|_| rng.normal() as f32).collect();

    // Warm the executable cache outside the timed region.
    rt.matvec(&a, rows, cols, &x, 1).unwrap();
    rt.matvec_native(&a, rows, cols, &x, 1).unwrap();

    let r = quick().items((rows * cols) as f64).run("pallas artifact", || {
        rt.matvec(black_box(&a), rows, cols, black_box(&x), 1).unwrap()
    });
    println!("{}", r.report());
    let r = quick()
        .items((rows * cols) as f64)
        .run("xla-native artifact (ablation)", || {
            rt.matvec_native(black_box(&a), rows, cols, black_box(&x), 1)
                .unwrap()
        });
    println!("{}", r.report());
    let r = quick().items((rows * cols) as f64).run("rust native loop", || {
        native_matmul(black_box(&a), rows, cols, black_box(&x), 1)
    });
    println!("{}", r.report());
}
