//! `cargo bench --bench sweep` — the batched sweep engine vs. the serial
//! per-cell path on the Fig. 6 grid (20 cells), emitting
//! `BENCH_sweep.json` to seed the perf trajectory (DESIGN.md §Perf).
//!
//! Serial = one `sim::run` per cell (fresh thread spawn per cell, a
//! barrier at each cell's slowest shard). Batched = every cell's shards
//! drained through one shared pool (`exec::BatchRunner`). Fused
//! (kernel v3) = the batched path with the whole grid compiled into one
//! column arena, killing the per-cell compile allocations. Identical
//! numerical results (bit-for-bit per cell at pinned `cell_streams`);
//! only the scheduling and allocation differ.

use std::time::Duration;

use coded_coop::exec::{BatchJob, BatchRunner};
use coded_coop::experiment::catalog;
use coded_coop::sim::{self, McOptions, SampleOrder};
use coded_coop::util::benchkit::{group, quick_mode, repo_root_record, write_json, Bench};

fn main() {
    group("sweep engine: batched shared pool vs serial per-cell (fig6 grid)");
    let quick = quick_mode();
    let trials = if quick { 1_000 } else { 5_000 };
    let spec = catalog::spec("fig6", trials, 2022).expect("catalog resolves fig6");
    let cells = spec.expand().expect("fig6 expands");
    let jobs: Vec<BatchJob> = cells
        .iter()
        .map(|c| BatchJob {
            scenario: c.scenario.clone(),
            plan: c.policy.build(&c.scenario).expect("plan builds"),
            seed: c.seed,
            trials: spec.trials,
            keep_samples: false,
            order: SampleOrder::TrialMajor,
            ziggurat: false,
        })
        .collect();
    let total_trials = (jobs.len() * spec.trials) as f64;
    println!(
        "grid: {} cells × {} trials ({} total MC trials per iteration)\n",
        jobs.len(),
        spec.trials,
        total_trials as u64
    );

    let measure = if quick {
        Duration::from_millis(600)
    } else {
        Duration::from_secs(3)
    };
    let serial = Bench::new()
        .warmup(Duration::from_millis(300))
        .measure_time(measure)
        .max_iters(20)
        .items(total_trials)
        .run("sweep::serial_per_cell", || {
            for j in &jobs {
                sim::run(
                    &j.scenario,
                    &j.plan,
                    &McOptions {
                        trials: j.trials,
                        seed: j.seed,
                        keep_samples: false,
                        threads: 0,
                        ziggurat: false,
                    },
                );
            }
        });
    println!("{}", serial.report());

    let runner = BatchRunner::default();
    let batched = Bench::new()
        .warmup(Duration::from_millis(300))
        .measure_time(measure)
        .max_iters(20)
        .items(total_trials)
        .run("sweep::batched_shared_pool", || {
            runner.run(&jobs).expect("batch run")
        });
    println!("{}", batched.report());

    let fused_runner = BatchRunner { fused: true, ..BatchRunner::default() };
    let fused = Bench::new()
        .warmup(Duration::from_millis(300))
        .measure_time(measure)
        .max_iters(20)
        .items(total_trials)
        .run("sweep::batched_fused_arena", || {
            fused_runner.run(&jobs).expect("fused batch run")
        });
    println!("{}", fused.report());

    let speedup = serial.mean.as_secs_f64() / batched.mean.as_secs_f64();
    println!("\nbatched/serial wall-time speedup: {speedup:.2}×");
    let fused_speedup = batched.mean.as_secs_f64() / fused.mean.as_secs_f64();
    println!("fused/batched wall-time speedup: {fused_speedup:.2}×");
    let out = repo_root_record("BENCH_sweep.json");
    write_json(&out, "sweep", &[serial, batched, fused]).expect("write BENCH_sweep.json");
    println!("wrote {out}");
}
