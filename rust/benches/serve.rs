//! `cargo bench --bench serve` — the serving event core at fleet scale:
//! hierarchical timer wheel vs. the binary-heap oracle on an overload
//! job stream, emitting `BENCH_serve.json` (jobs/s) for
//! `python/bench_gate.py` (DESIGN.md §Perf).
//!
//! Both cores produce bit-identical results (the serving tests pin it);
//! only the event-queue data structure differs, so the throughput gap
//! is pure scheduling overhead. The stream is the overload regime the
//! refactor targets: burst arrivals past saturation, bounded record
//! ring, sketch-backed tails.

use std::time::Duration;

use coded_coop::config::{CommModel, Scenario};
use coded_coop::policy::PolicySpec;
use coded_coop::serve::{self, ArrivalProcess, EventQueueKind, ServeConfig};
use coded_coop::util::benchkit::{group, quick_mode, repo_root_record, write_json, Bench};

fn main() {
    group("serving event core: timer wheel vs binary heap (overload stream)");
    let quick = quick_mode();
    let jobs_per_master = if quick { 2_000 } else { 10_000 };
    let s = Scenario::small_scale(5, 2.0, CommModel::Stochastic);
    let mut cfg = ServeConfig::new(PolicySpec::new(
        "dedi-iter",
        coded_coop::assign::ValueModel::Markov,
        "markov",
    ));
    cfg.process = ArrivalProcess::Burst;
    cfg.load_factor = 1.5;
    cfg.jobs = jobs_per_master;
    cfg.record_cap = 512; // O(1) memory: the regime the wheel targets
    let total_jobs = (s.n_masters() * jobs_per_master) as f64;
    println!(
        "stream: {} masters × {} jobs, burst arrivals at 1.5× load\n",
        s.n_masters(),
        jobs_per_master
    );

    let measure = if quick {
        Duration::from_millis(600)
    } else {
        Duration::from_secs(3)
    };

    cfg.queue = EventQueueKind::Heap;
    let heap_cfg = cfg.clone();
    let heap = Bench::new()
        .warmup(Duration::from_millis(300))
        .measure_time(measure)
        .max_iters(20)
        .items(total_jobs)
        .run("serve/heap", || {
            serve::run(&s, &heap_cfg).expect("heap serve run")
        });
    println!("{}", heap.report());

    cfg.queue = EventQueueKind::Wheel;
    let wheel_cfg = cfg.clone();
    let wheel = Bench::new()
        .warmup(Duration::from_millis(300))
        .measure_time(measure)
        .max_iters(20)
        .items(total_jobs)
        .run("serve/wheel", || {
            serve::run(&s, &wheel_cfg).expect("wheel serve run")
        });
    println!("{}", wheel.report());

    let speedup = heap.mean.as_secs_f64() / wheel.mean.as_secs_f64();
    println!("\nwheel/heap wall-time speedup: {speedup:.2}×");
    let out = repo_root_record("BENCH_serve.json");
    write_json(&out, "serve", &[heap, wheel]).expect("write BENCH_serve.json");
    println!("wrote {out}");
}
