//! `cargo bench --bench engine` — the kernel perf trajectory (v1→v3).
//!
//! Measures trials/second of the Monte-Carlo engine on the paper's three
//! scenario shapes (fig4-style small scale, large scale, EC2 with
//! stragglers), old kernel vs new:
//!
//! * `legacy`        — the pre-v2 AoS kernel (`sim::engine::oracle`),
//!                     per-trial sort, per-run thread spawn;
//! * `v2-trial-major`— the SoA kernel, selection scan, shared pool;
//!                     bit-for-bit identical results to `legacy`;
//! * `v2-blocked`    — the SoA kernel with column-filled B-trial blocks
//!                     (same distribution, different bits);
//! * `v3-chunked`    — v2-blocked through the SIMD-width-chunked fill
//!                     primitives plus thread-local scratch reuse
//!                     (bit-identical to `v2-blocked`);
//! * `v3-zigg`       — `v3-chunked` with the ziggurat exponential
//!                     sampler (same distribution, different bits).
//!
//! Kernel rows pin `threads: 1` so the comparison is the sampling loop,
//! not the scheduler; one all-cores pair quantifies the pool-reuse win on
//! short runs. Per-delay-family rows (`fam-*` tags: Weibull, Pareto,
//! bimodal, trace-driven on the small scenario) track the family-tagged
//! kernel paths; the gate treats them as informational — only the
//! shifted-exp `small`/`large`/`ec2` v2-vs-legacy ratios are hard.
//! Writes `BENCH_engine.json` to the **repo root** — the
//! perf-trajectory record CI archives and gates on
//! (`python/bench_gate.py`). `BENCH_QUICK=1` shrinks the measurement for
//! CI smoke runs.

use std::time::Duration;

use coded_coop::assign::ValueModel;
use coded_coop::config::{CommModel, Scenario, Transform};
use coded_coop::model::dist::{FamilyKind, TraceDist};
use coded_coop::plan::{self, LoadMethod, PlanSpec, Policy};
use coded_coop::sim::engine::oracle;
use coded_coop::sim::{self, McOptions, SampleOrder};
use coded_coop::util::benchkit::{
    group, quick_mode, repo_root_record, write_json, Bench, BenchResult,
};
use coded_coop::util::rng::Rng;

fn bench(trials: usize) -> Bench {
    let (warm, measure) = if quick_mode() {
        (Duration::from_millis(100), Duration::from_millis(400))
    } else {
        (Duration::from_millis(300), Duration::from_secs(2))
    };
    Bench::new()
        .warmup(warm)
        .measure_time(measure)
        .items(trials as f64)
}

fn opts(trials: usize, threads: usize) -> McOptions {
    McOptions {
        trials,
        seed: 2022,
        keep_samples: false,
        threads,
        ziggurat: false,
    }
}

fn kernel_rows(
    results: &mut Vec<BenchResult>,
    tag: &str,
    s: &Scenario,
    p: &plan::Plan,
    trials: usize,
) {
    group(&format!("engine kernels: {tag} ({trials} trials, 1 stream)"));
    let o = opts(trials, 1);
    let r = bench(trials).run(&format!("{tag}/legacy"), || {
        oracle::run(s, p, &o).system.mean()
    });
    println!("{}", r.report());
    results.push(r);
    let r = bench(trials).run(&format!("{tag}/v2-trial-major"), || {
        sim::run_ordered(s, p, &o, SampleOrder::TrialMajor).system.mean()
    });
    println!("{}", r.report());
    results.push(r);
    let r = bench(trials).run(&format!("{tag}/v2-blocked"), || {
        sim::run_ordered(s, p, &o, SampleOrder::Blocked).system.mean()
    });
    println!("{}", r.report());
    results.push(r);
    let r = bench(trials).run(&format!("{tag}/v3-chunked"), || {
        sim::run_ordered(s, p, &o, SampleOrder::Chunked).system.mean()
    });
    println!("{}", r.report());
    results.push(r);
    let oz = McOptions { ziggurat: true, ..o };
    let r = bench(trials).run(&format!("{tag}/v3-zigg"), || {
        sim::run_ordered(s, p, &oz, SampleOrder::Chunked).system.mean()
    });
    println!("{}", r.report());
    results.push(r);
}

fn main() {
    let trials = if quick_mode() { 4_000 } else { 20_000 };
    let mut results: Vec<BenchResult> = Vec::new();

    let dedi = PlanSpec {
        policy: Policy::DediIter,
        values: ValueModel::Markov,
        loads: LoadMethod::Markov,
    };

    // fig4-style small scale (M=2, N=5) — the acceptance scenario.
    let s = Scenario::small_scale(2022, 2.0, CommModel::Stochastic);
    let p = plan::build(&s, &dedi);
    kernel_rows(&mut results, "small", &s, &p, trials);

    // Large scale (M=4, N=50): selection scan beyond the sort cutoff.
    let s = Scenario::large_scale(2022, 2.0, CommModel::Stochastic);
    let p = plan::build(&s, &dedi);
    kernel_rows(&mut results, "large", &s, &p, trials);

    // EC2 with the straggler mixture: extra uniform draw per sample.
    let s = Scenario::ec2(40, 10, true);
    let p = plan::build(&s, &dedi);
    kernel_rows(&mut results, "ec2", &s, &p, trials);

    // Per-delay-family rows (small scenario, mean-matched families):
    // the family-tagged kernel paths vs the same oracle.
    let small = || Scenario::small_scale(2022, 2.0, CommModel::Stochastic);
    for (tag, kind) in [
        ("fam-weibull", FamilyKind::Weibull { shape: 0.6 }),
        ("fam-pareto", FamilyKind::Pareto { alpha: 2.5 }),
        (
            "fam-bimodal",
            FamilyKind::Bimodal {
                prob: 0.02,
                slow: 20.0,
            },
        ),
    ] {
        let s = small().transformed(&[Transform::Family(kind)]);
        let p = plan::build(&s, &dedi);
        kernel_rows(&mut results, tag, &s, &p, trials);
    }
    // Trace-driven family: quantile lookups per draw over a 1k trace.
    let mut s = small();
    let mut rng = Rng::new(7);
    let samples: Vec<f64> = (0..1_000).map(|_| 0.2 + rng.exp(4.0)).collect();
    let id = s.add_trace(TraceDist::from_samples("syn", samples).unwrap());
    let s = s.transformed(&[Transform::Family(FamilyKind::Trace { id })]);
    let p = plan::build(&s, &dedi);
    kernel_rows(&mut results, "fam-trace", &s, &p, trials);

    // Scheduler row: short all-cores runs, where the legacy per-run
    // thread spawn dominates and the shared pool pays off.
    group("engine scheduler: short all-cores runs (small scenario)");
    let s = Scenario::small_scale(2022, 2.0, CommModel::Stochastic);
    let p = plan::build(&s, &dedi);
    let short = 2_000;
    let o = opts(short, 0);
    let r = bench(short).run("small-short/legacy-spawn-per-run", || {
        oracle::run(&s, &p, &o).system.mean()
    });
    println!("{}", r.report());
    results.push(r);
    let r = bench(short).run("small-short/v2-shared-pool", || {
        sim::run(&s, &p, &o).system.mean()
    });
    println!("{}", r.report());
    results.push(r);

    let out = repo_root_record("BENCH_engine.json");
    write_json(&out, "engine", &results).expect("write BENCH_engine.json");
    println!("\nwrote {out}");
}
