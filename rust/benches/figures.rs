//! `cargo bench --bench figures` — end-to-end regeneration benches, one
//! per paper table/figure (DESIGN.md §4). Each bench runs the same code
//! the `coded-coop figure` harness uses (reduced trial counts so the
//! bench suite completes in minutes) and reports wall time; throughput is
//! Monte-Carlo trials per second.

use std::time::Duration;

use coded_coop::figures::{self, FigureOptions};
use coded_coop::util::benchkit::{group, Bench};

fn main() {
    group("figure regeneration (reduced trials)");
    let opts = FigureOptions {
        trials: 10_000,
        seed: 2022,
        fit_samples: 50_000,
        threads: 0,
    };
    for id in figures::ALL_IDS {
        let r = Bench::new()
            .warmup(Duration::from_millis(100))
            .measure_time(Duration::from_secs(2))
            .max_iters(20)
            .items(opts.trials as f64)
            .run(&format!("figure::{id}"), || {
                figures::run(id, &opts).expect("figure must regenerate")
            });
        println!("{}", r.report());
    }
    println!("\n(fig4a includes the λ-sweep grid optimum; fig5 keeps CDF samples)");
}
